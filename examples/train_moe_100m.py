"""End-to-end driver: train a ~100M-parameter MoE transformer for a few
hundred steps with the paper's sort-based expert dispatch, async
checkpointing and crash recovery (brief deliverable b).

  PYTHONPATH=src python examples/train_moe_100m.py [--steps 200]
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import tempfile          # noqa: E402

from repro.configs import get_config                    # noqa: E402
from repro.launch.mesh import make_mesh_shape           # noqa: E402
from repro.launch.train import train                    # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M-param MoE: granite family scaled down (16 experts of d_ff=512,
    # d_model=512, 8 layers, 32k vocab) with EP over model axis = 4.
    cfg = dataclasses.replace(
        get_config("granite-moe-1b-a400m"), name="moe-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=512,
        vocab=32768, n_experts=16, top_k=4, remat="none")
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.0f}M params "
          f"({cfg.active_param_count()/1e6:.0f}M active), sort dispatch")

    mesh = make_mesh_shape((2, 4), ("data", "model"))
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="moe100m_ckpt_")
    final, losses = train(cfg, mesh, steps=args.steps, batch=8, seq=128,
                          ckpt_dir=ckpt, ckpt_every=50)
    print(f"[example] finished {final} steps; "
          f"loss {losses[0]:.3f} → {losses[-1]:.3f} (ckpts in {ckpt})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
