"""Cluster-scale sorting scenario: length-balanced batch construction for a
training data pipeline (the paper's technique in the data layer), plus a
robustness demo on adversarial instances.

  PYTHONPATH=src python examples/sort_cluster.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np                                     # noqa: E402

from repro.core import SortConfig, psort              # noqa: E402
from repro.data.pipeline import length_balanced_batches  # noqa: E402
from repro.data.distributions import generate_instance  # noqa: E402


def main():
    # 1) length-balanced batching: zipf-ish sequence lengths (heavy dups —
    #    the robustness case), batch 32
    r = np.random.default_rng(0)
    lengths = np.minimum(64 + (r.zipf(1.5, size=4096) % 1984), 2048)
    batches, waste_naive, waste_sorted = length_balanced_batches(
        lengths, batch=32, p=8)
    print(f"[example] padding waste: naive {waste_naive:.1%} → "
          f"length-sorted {waste_sorted:.1%} "
          f"({batches.shape[0]} batches of 32)")
    assert waste_sorted < waste_naive

    # 2) the robustness demo: the adversarial instances sort exactly
    for inst in ("Mirrored", "AllToOne", "DeterDupl", "Zero", "Staggered"):
        x = generate_instance(inst, 8, 8192).astype(np.int32)
        out, info = psort(x, config=SortConfig(p=8, algorithm="rquick"),
                          return_info=True)
        assert (np.asarray(out) == np.sort(x)).all() and info["overflow"] == 0
        print(f"[example] rquick sorted {inst:10s} "
              f"(balance {info['balance']:.2f})")


if __name__ == "__main__":
    main()
