"""Quickstart: robust distributed sorting with repro.core.psort.

Sorts every paper input instance with the auto-selected algorithm on 8
emulated TPU devices and prints the selection + balance.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np                                  # noqa: E402

from repro.core import SortConfig, psort, select_algorithm  # noqa: E402
from repro.data.distributions import INSTANCES, generate_instance  # noqa: E402

P = 8


def main():
    print(f"{'instance':14s} {'n':>7s} {'algorithm':10s} {'sorted':6s} "
          f"{'balance':7s} {'overflow'}")
    for inst in sorted(INSTANCES):
        for n in (4, 1024, 16384):
            x = generate_instance(inst, P, n).astype(np.int32)
            out, info = psort(x, config=SortConfig(p=P, algorithm="auto"),
                              return_info=True)
            ok = bool((np.asarray(out) == np.sort(x)).all())
            print(f"{inst:14s} {n:7d} {info['algorithm']:10s} {str(ok):6s} "
                  f"{info['balance']:7.2f} {info['overflow']}")
            assert ok and info["overflow"] == 0

    # high emulated PE counts: the sim backend is not capped by devices
    x = generate_instance("Staggered", 128, 128 * 32).astype(np.int32)
    out = psort(x, config=SortConfig(p=128, algorithm="rquick",
                                     backend="sim"))
    ok = bool((np.asarray(out) == np.sort(x)).all())
    print(f"\nsim backend: p=128 rquick sorted={ok}")
    assert ok

    # the paper's headline: algorithm choice depends on n/p
    print("\nAuto-selection regimes at p=262144 (paper Fig. 1 structure):")
    for e in (-8, -2, 0, 4, 10, 16, 22):
        n = max(1, int(262144 * 2.0 ** e))
        print(f"  n/p = 2^{e:>3d}  →  {select_algorithm(n, 262144)}")


if __name__ == "__main__":
    main()
