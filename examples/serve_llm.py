"""Serve a small model with batched requests (brief deliverable b):
rwkv6-family reduced config decoding 64 tokens for a batch of 8 requests,
reporting p50/p99 latency and throughput.

  PYTHONPATH=src python examples/serve_llm.py [--arch rwkv6-1.6b]
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse                                   # noqa: E402

from repro.configs import get_config, smoke_variant  # noqa: E402
from repro.launch.mesh import make_mesh_shape     # noqa: E402
from repro.launch.serve import serve              # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="2,4")
    args = ap.parse_args()
    cfg = smoke_variant(get_config(args.arch))
    dd, mm = (int(x) for x in args.mesh.split(","))
    mesh = make_mesh_shape((dd, mm), ("data", "model"))
    toks, stats = serve(cfg, mesh, batch=args.batch, tokens=args.tokens)
    print(f"[example] generated {toks.shape} tokens; stats: {stats}")


if __name__ == "__main__":
    main()
