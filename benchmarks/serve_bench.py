"""Mixed-query serving throughput benchmark → ``BENCH_serve.json``.

Measures, per p, the wall-clock of answering a query micro-batch two
ways — the sort-free selection fast path of ``core/queries.py`` versus
sorting first with ``psort`` and indexing — plus the counting queries and
a mixed-stream :class:`repro.launch.sort_serve.SortService` drain.  Cells
land in the same ``bench[p][name][e]`` shape as ``BENCH_calibrate.json``
(e = log2(n/p), µs per cell) and are gated by ``tools/check_bench.py``
in the CI ``serve`` lane (with ``--fail-on-dropped``: the committed
baseline's cells must all be produced, every run).

The headline acceptance cells: ``serve/top_k`` and ``serve/percentile``
must beat their ``*_fullsort`` counterparts at p ∈ {64, 256} — the
selection path's device work is polylog in n while the sort's is Ω(n/p).

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
      --bench-json BENCH_fresh_serve.json
  PYTHONPATH=src python benchmarks/serve_bench.py   # full iters, CI grid

``--smoke`` only drops the timed iterations to 1 — the (p, e) cell grid
is identical, so smoke runs still produce every gated cell.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

import jax

from repro.core import SortConfig, psort
from repro.core.queries import (percentile, range_query, rank_of_key,
                                shard_data, top_k)
from repro.launch.sort_serve import SortService

BATCH = 8           # queries per micro-batch in the per-kind cells
MIX_QUERIES = 24    # stream length of the serve/mixed cell


def _best_us(fn, iters: int, reps: int = 1) -> float:
    """Fastest observed wall-clock of ``fn`` in µs — min over ``iters``
    measurements of a ``reps``-call chain.  Min, not median: the gate
    compares ratios across runner generations, and the minimum is the
    measurement least contaminated by scheduler noise.  ``reps`` chains
    calls inside one measurement so sub-millisecond dispatch-bound cells
    (the counting queries) average out per-call jitter."""
    fn()                                          # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        ts.append(time.perf_counter() - t0)
    return float(min(ts)) / reps * 1e6


def bench_p(p: int, e: int, iters: int, seed: int = 0,
            cheap_iters: int = 3):
    """All serve cells for one (p, e): returns {name: us}.

    ``iters`` drives the heavy full-sort cells (the expensive part a
    smoke run cuts to 1); the millisecond-scale selection/counting cells
    always run ``cheap_iters`` measurements — they cost nothing and the
    gate needs the extra samples for a stable minimum."""
    n = p << e
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 1 << 32, size=n).astype(np.int64)
    data = shard_data(keys, p)
    ks = np.linspace(1, min(64, n), BATCH).astype(np.int64)
    qs = np.linspace(0.0, 100.0, BATCH)
    probe = keys[rng.integers(0, n, size=BATCH)]
    lo = np.minimum(probe, keys[rng.integers(0, n, size=BATCH)])
    hi = np.maximum(probe, keys[rng.integers(0, n, size=BATCH)])

    def sorted_now():
        # the fullsort path's per-query-batch cost: sort, then answer
        # locally (post-warmup, so the psort jit cache is hot — this
        # times device work, not tracing).  rquick is pinned because it
        # is the fastest full sort at these (n, p) on the sim backend —
        # the selection cells must beat the *best* sorting comparator,
        # not whatever the regime model happens to pick.
        return np.asarray(jax.block_until_ready(
            psort(keys, config=SortConfig(p=p, algorithm="rquick",
                                          backend="sim"))))

    def topk_fullsort():
        s = sorted_now()                   # one sort answers the batch
        return [s[n - k:] for k in ks]

    def pct_fullsort():
        s = sorted_now()
        return s[np.floor(qs / 100.0 * (n - 1)).astype(np.int64)]

    ic = max(iters, cheap_iters)
    out = {
        "serve/top_k": _best_us(lambda: top_k(data, ks), ic, reps=3),
        "serve/top_k_fullsort": _best_us(topk_fullsort, iters),
        "serve/percentile": _best_us(lambda: percentile(data, qs), ic,
                                     reps=3),
        "serve/percentile_fullsort": _best_us(pct_fullsort, iters),
        "serve/rank_of_key": _best_us(
            lambda: rank_of_key(data, probe), ic, reps=10),
        "serve/range_query": _best_us(
            lambda: range_query(data, lo, hi), ic, reps=10),
        "serve/sort": _best_us(sorted_now, iters),
    }

    def mixed():
        svc = SortService(keys, p, backend="sim", policy="selection")
        r = np.random.default_rng(seed + 1)
        for _ in range(MIX_QUERIES):
            kind = ("top_k", "percentile", "rank_of_key",
                    "range_query")[r.integers(4)]
            arg = {"top_k": int(ks[r.integers(BATCH)]),
                   "percentile": float(qs[r.integers(BATCH)]),
                   "rank_of_key": int(probe[r.integers(BATCH)]),
                   "range_query": (int(lo[r.integers(BATCH)]),
                                   int(hi[r.integers(BATCH)]))}[kind]
            svc.submit(kind, arg)
        svc.drain()

    out["serve/mixed"] = _best_us(mixed, ic) / MIX_QUERIES
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--p", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--e", type=int, nargs="+", default=[6],
                    help="log2(n/p) per cell")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="1 timed iteration of the heavy full-sort cells "
                         "(same cell grid; cheap cells keep 3 iterations)")
    ap.add_argument("--machine", default="local")
    ap.add_argument("--bench-json", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    iters = 1 if args.smoke else args.iters

    bench = {}
    for p in args.p:
        for e in args.e:
            cells = bench_p(p, e, iters, seed=args.seed)
            for name, us in cells.items():
                bench.setdefault(str(p), {}).setdefault(name, {})[str(e)] \
                    = us
            print(f"# p={p} e={e}: " + "  ".join(
                f"{k.split('/')[1]}={v:.0f}us" for k, v in cells.items()))
            for kind in ("top_k", "percentile"):
                sel = cells[f"serve/{kind}"]
                full = cells[f"serve/{kind}_fullsort"]
                tag = "beats" if sel < full else "LOSES TO"
                print(f"#   {kind}: selection {tag} fullsort "
                      f"({sel:.0f}us vs {full:.0f}us, "
                      f"{full / max(sel, 1e-9):.1f}x)")

    with open(args.bench_json, "w") as f:
        json.dump({"machine": args.machine, "host": platform.node(),
                   "p": args.p, "bench": bench}, f, indent=2,
                  sort_keys=True)
    print(f"# wrote {args.bench_json}")


if __name__ == "__main__":
    main()
