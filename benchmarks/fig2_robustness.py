"""Paper Fig. 2: robust vs non-robust variants.

2a  RQuick / NTB-Quick (no shuffle, no tie-break)
2b  RAMS / NTB-AMS (no sample tie-breaking)
2d  RAMS / SSort and NS-SSort (oracle splitters)

`derived` reports the ratio (or the failure mode of the non-robust
variant: OVERFLOW(n) — our static-capacity analogue of the paper's
deadlocks/crashes).
"""
import numpy as np

from repro.core.api import SortConfig, psort
from repro.data.distributions import generate_instance

from common import emit, timeit

P = 8


def run_pair(tag, inst, n, robust_algo, nonrobust_algo, robust_kw=None,
             nonrobust_kw=None):
    x = generate_instance(inst, P, n).astype(np.int32)
    cfg_r = SortConfig(p=P, algorithm=robust_algo,
                       algo_kw=robust_kw or {})
    us_r = timeit(lambda: np.asarray(psort(x, config=cfg_r)))
    _, info_r = psort(x, config=cfg_r, return_info=True)
    assert info_r["overflow"] == 0, (tag, inst, n)
    try:
        cfg_n = SortConfig(p=P, algorithm=nonrobust_algo,
                           algo_kw=nonrobust_kw or {})
        _, info_n = psort(x, config=cfg_n, return_info=True)
        if info_n["overflow"] > 0:
            emit(f"{tag}/{inst}/n{n}", us_r,
                 f"nonrobust OVERFLOW({info_n['overflow']})")
            return
        us_n = timeit(lambda: np.asarray(psort(x, config=cfg_n)))
        emit(f"{tag}/{inst}/n{n}", us_r, f"ratio={us_r / us_n:.3f}")
    except Exception as e:   # noqa: BLE001
        emit(f"{tag}/{inst}/n{n}", us_r, f"nonrobust FAIL:{type(e).__name__}")


def main():
    for inst in ["Uniform", "Staggered", "DeterDupl", "BucketSorted",
                 "Mirrored"]:
        for n in [64, 1024, 8192]:
            run_pair("fig2a_rquick_vs_ntb", inst, n, "rquick", "ntb-quick")
    for inst in ["Uniform", "DeterDupl", "BucketSorted"]:
        for n in [1024, 8192]:
            run_pair("fig2b_rams_vs_ntb", inst, n, "rams", "ntb-ams")
    for inst in ["Uniform", "AllToOne", "Zero"]:
        for n in [1024, 8192]:
            run_pair("fig2d_rams_vs_ssort", inst, n, "rams", "ssort")


if __name__ == "__main__":
    main()
