"""Beyond-paper: MoE token dispatch — sort-based (paper machinery) vs the
dense one-hot einsum baseline, on the granite smoke config over a (2,4)
(data, model) mesh.  derived = speedup + HLO collective bytes of the
distributed path.  The ``ep_sim_subgroup`` cell runs the same dispatch
body over an *emulated* (d=4, ep=4) mesh via ``comm.sim_map(mesh=...)`` —
16 PEs on 8 devices, each data row sorting within its own expert-parallel
subgroup (the multi-tenant layout).
"""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config, smoke_variant
from repro.launch import hlo_cost
from repro.models import moe as M

from common import emit, timeit


def main():
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts, jnp.float32)
    x = jax.random.normal(key, (4, 64, cfg.d_model), jnp.float32)
    devs = jax.devices()[:8]
    mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))

    f_dense = jax.jit(lambda xx: M.moe_dense(xx, p, cfg)[0])
    f_local = jax.jit(lambda xx: M.moe_local(xx, p, cfg)[0])
    with mesh:
        f_ep = jax.jit(lambda xx: M.moe_ep_shardmap(
            xx, p, cfg, mesh, data_axes=("data",))[0])
        us_ep = timeit(lambda: np.asarray(f_ep(x)))
        comp = f_ep.lower(x).compile()
    us_dense = timeit(lambda: np.asarray(f_dense(x)))
    us_local = timeit(lambda: np.asarray(f_local(x)))
    a = hlo_cost.analyze(comp.as_text())
    # emulated (d, ep) subgroup mesh: 4 tenants × 4-way expert parallelism
    f_sim = jax.jit(lambda xx: M.moe_ep_sim(xx, p, cfg, d=4,
                                            ep=min(4, cfg.n_experts))[0])
    us_sim = timeit(lambda: np.asarray(f_sim(x)))
    emit("moe/dense_onehot", us_dense, "E×FLOPs baseline")
    emit("moe/local_sortgroup", us_local,
         f"speedup_vs_dense={us_dense / us_local:.2f}x")
    emit("moe/ep_sort_dispatch", us_ep,
         f"a2a_bytes={sum(a['collective_bytes'].values()):.0f}")
    emit("moe/ep_sim_subgroup", us_sim,
         f"mesh=4x{min(4, cfg.n_experts)}_emulated")


if __name__ == "__main__":
    main()
