"""Paper Fig. 1: running times of each algorithm across input sizes and
instances.  Measured on p emulated CPU devices (relative regime structure);
`derived` = the v5e α/β-model prediction at p=262144 for the same n/p
(core/selection.py) — the quantity Table I ranks.
"""
import numpy as np

from repro.core.api import SortConfig, psort
from repro.core import selection
from repro.data.distributions import generate_instance

from common import emit, timeit

ALGOS = ["gatherm", "allgatherm", "rfis", "rquick", "rams", "bitonic",
         "ssort"]
INSTANCES = ["Uniform", "BucketSorted", "DeterDupl", "Staggered"]
P = 8
NPP = [0.125, 1, 8, 64, 512, 4096]       # n/p sweep (sparse → large)


def model_time(algo, n, p=262144):
    fn = {
        "gatherm": selection.cost_gatherm, "allgatherm": selection.cost_allgatherm,
        "rfis": selection.cost_rfis, "rquick": selection.cost_rquick,
        "rams": selection.cost_rams, "bitonic": selection.cost_bitonic,
        "ssort": selection.cost_ssort}[algo]
    return fn(max(1, int(n / P * p)), p)


def main():
    for inst in INSTANCES:
        for npp in NPP:
            n = max(0, int(npp * P))
            x = generate_instance(inst, P, n).astype(np.int32)
            for algo in ALGOS:
                if algo in ("rfis", "allgatherm", "gatherm") and npp > 512:
                    # out of the algorithm's regime (RFIS tie-refinement is
                    # O((n/√p)²); gather variants are O(n)-volume) — the
                    # paper's Fig. 1 likewise shows them only while relevant
                    emit(f"fig1/{inst}/npp{npp}/{algo}", float("nan"),
                         "SKIP:out-of-regime")
                    continue
                try:
                    cfg = SortConfig(p=P, algorithm=algo)
                    us = timeit(lambda: np.asarray(psort(x, config=cfg)))
                    ok = (np.asarray(psort(x, config=cfg))
                          == np.sort(x)).all()
                    status = f"{model_time(algo, n):.2e}s@262144" if ok \
                        else "MIS-SORTED"
                except Exception as e:   # noqa: BLE001 — failures are data here
                    us, status = float("nan"), f"FAIL:{type(e).__name__}"
                emit(f"fig1/{inst}/npp{npp}/{algo}", us, status)


if __name__ == "__main__":
    main()
