"""Paper Table I: latency (startup count) and communication volume per PE,
measured two independent ways against the asymptotic prediction:

  * *compiled HLO* of each algorithm (collective ops counted with the
    trip-count-aware analyzer), and
  * the *counted collective trace* (``repro.core.api.trace_collectives``
    — the call-site instrumentation ``benchmarks/calibrate.py`` fits the
    machine profile from).

derived = "colls=<count> cnt=<counted> (pred O(<latency>)),
           wire=<bytes/PE> B (pred O(<volume>) = <words> words)"
"""
import numpy as np

import jax
from repro.core import types as ct
from repro.core.api import (SortConfig, _algorithm_fn, default_mesh,
                            trace_collectives)
from repro.launch import hlo_cost
from jax.sharding import PartitionSpec as P

from common import emit

P_DEV = 8
NPP = 256


def lower_algo(algorithm):
    from repro.runtime.compat import shard_map
    mesh = default_mesh(P_DEV)
    fn = _algorithm_fn(algorithm)

    def body(keys):
        sh = ct.make_shard(keys[0], capacity=2 * NPP)
        out, ovf = fn(sh, "sort", P_DEV)
        return out.keys[None, :2 * NPP], ovf[None]

    keys = jax.ShapeDtypeStruct((P_DEV, NPP), jax.numpy.uint32)
    with mesh:
        c = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("sort"),),
                              out_specs=(P("sort"), P("sort")))
                    ).lower(keys).compile()
    return hlo_cost.analyze(c.as_text())


PRED = {   # Table I rows: (latency O(·), comm volume O(·) in words/PE)
    "gatherm": ("log p", "n", lambda n, p: n),
    "allgatherm": ("log p", "n", lambda n, p: n),
    "rfis": ("log p", "n/sqrt(p)", lambda n, p: n / np.sqrt(p)),
    "rquick": ("log^2 p", "(n/p)log p", lambda n, p: n / p * np.log2(p)),
    "rams": ("k log_k p", "(n/p)log_k p", lambda n, p: 2 * n / p),
    "bitonic": ("log^2 p", "(n/p)log^2 p",
                lambda n, p: n / p * np.log2(p) ** 2),
    "ssort": (">= p", ">= n/p", lambda n, p: n / p),
}


def main():
    n = NPP * P_DEV
    for algo, (lat, vol, vol_fn) in PRED.items():
        try:
            a = lower_algo(algo)
        except Exception as e:   # noqa: BLE001
            emit(f"table1/{algo}", float("nan"), f"FAIL:{type(e).__name__}")
            continue
        colls = sum(a["collective_counts"].values())
        wire = sum(a["collective_bytes"].values())
        pred_words = vol_fn(n, P_DEV)
        try:
            tr = trace_collectives(n, SortConfig(p=P_DEV, algorithm=algo))
            counted = f"cnt={tr.launches}/{tr.wire_bytes()}B"
        except Exception as e:   # noqa: BLE001
            counted = f"cnt=FAIL:{type(e).__name__}"
        emit(f"table1/{algo}", 0.0,
             f"colls={colls:.0f} {counted} (pred O({lat})) wire={wire:.0f}B/PE "
             f"(pred O({vol})={pred_words:.0f}w={4 * pred_words:.0f}B)")


if __name__ == "__main__":
    main()
