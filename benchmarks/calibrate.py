"""Measurement-driven calibration of the α/β cost model (ROADMAP items 1–2).

Two measurement phases on the **sim backend** (single process, chunked
vmap over emulated PEs — p = 64…1024):

1. **Primitive microbenchmarks** → the machine profile.  The way machine
   constants are derived in "Practical Massively Parallel Sorting"
   (arXiv 1410.6754): each parameter is isolated by a collective that
   depends on (almost) nothing else —

     * α      — per-launch cost of a chained tiny-payload ``ppermute``;
     * β      — payload slope of the same ``ppermute`` (s per word/PE);
     * α_c,
       α_hop — tiny-payload ``all_gather`` launch cost regressed on the
               torus pipeline depth p^(1/3) across the swept p;
     * local_rate — ``jnp.sort`` throughput in model words (m·lg m / t).

   The result is a measured :class:`repro.core.selection.CostModel`
   written to ``profiles/<machine>.json`` (load with ``CostModel.load``,
   pass to ``select_algorithm`` / ``psort(algorithm="auto",
   cost_model=...)``).

2. **Algorithm sweep** → crossover validation + the CI perf artifact.
   The four regime algorithms (GatherM / RFIS / RQuick / RAMS) run over
   n/p × p, collecting per cell the counted collective trace
   (``repro.core.api.trace_collectives`` — the measured Table I) and
   wall-clock.  The script reports predicted-vs-measured regime winners
   per (n/p, p) (the Fig. 1 analogue) and dumps every cell into
   ``BENCH_calibrate.json``.  A whole-program NNLS fit of
   ``t ≈ α·p2p + α_c·fused + α_hop·hops + β·words + local/rate`` over the
   sweep cells is stashed in the profile's ``meta`` as a diagnostic — on
   a CPU sim host it degenerates (wall-clock is dominated by vectorized
   data movement, so the launch terms are unidentifiable), which is
   exactly why the profile itself comes from the microbenchmarks.

A third, optional phase (``--nested P_OUTER P_INNER``) runs the
**two-tier** measurement on a nested (inter × intra) sim mesh: per-axis
primitive microbenchmarks fit distinct inner/outer α and β into the
profile (the ``*_inner`` fields of :class:`CostModel`, charged to the
intra-axis levels of hierarchical RAMS by ``cost_rams(mesh_shape=...)``),
and a nested-vs-flat RAMS sweep adds ``rams@PoxPi`` wall-clock cells next
to the flat oracle so ``tools/check_bench.py`` gates the hierarchical
path too.

Typical runs::

    PYTHONPATH=src python benchmarks/calibrate.py --p 64 256 1024
    PYTHONPATH=src python benchmarks/calibrate.py --p 64 --fast
    PYTHONPATH=src python benchmarks/calibrate.py --p 64 256 --nested 8 8
    PYTHONPATH=src python benchmarks/calibrate.py --experiments-only

The p = 1024 column compiles ~20 programs of 1024 emulated PEs; expect
10–20 minutes for the full three-p run on a laptop-class CPU.
"""
import argparse
import json
import math
import os
import platform
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import emit, timeit                                    # noqa: E402

import jax                                                         # noqa: E402
import jax.numpy as jnp                                            # noqa: E402

from repro.core import comm, selection                             # noqa: E402
from repro.core.api import SortConfig, psort, trace_collectives    # noqa: E402
from repro.core.selection import CostModel                         # noqa: E402
from repro.data.distributions import generate_instance             # noqa: E402

ALGOS = ("gatherm", "rfis", "rquick", "rams")

# n/p exponents (log2) per emulated PE count.  The 1024 column is thinned:
# each cell is a fresh XLA compile of a 1024-PE program.
EXPS = {
    64: [-8, -5, -3, -1, 0, 1, 2, 4, 6],
    256: [-8, -5, -3, -1, 0, 1, 2, 4, 6],
    1024: [-8, -3, -1, 0, 2, 4],
}
EXPS_FAST = [-3, 0, 2]


def eligible(algo: str, e: int, p: int) -> bool:
    """Measurement windows: each algorithm is swept over its regime plus a
    margin for locating the crossover, not over grid cells where it is
    pathological (GatherM's concentrated output at dense n, RFIS's
    O((n/√p)²) tie ranking)."""
    if algo == "gatherm":
        return e <= 0
    if algo == "rfis":
        return e <= (4 if p >= 1024 else 6)
    if algo == "rams":
        return e >= 0
    return True


def cell_features(n: int, p: int, algo: str, mesh_shape=None,
                  **algo_kw) -> dict:
    """Counted-trace feature vector of the cell *as timed* — extra
    ``algo_kw`` (e.g. an explicit ``level_bits``) must match the psort
    call so the NNLS fit regresses wall-clock against the schedule that
    actually ran."""
    if mesh_shape is not None:
        cfg = SortConfig(mesh_shape=mesh_shape, algorithm=algo,
                         algo_kw=algo_kw)
    else:
        cfg = SortConfig(p=p, algorithm=algo, algo_kw=algo_kw)
    tr = trace_collectives(n, cfg)
    npp = n / p
    return {
        "p2p": tr.p2p_launches,
        "fused": tr.fused_launches,
        "hops": tr.fused_hops(p),
        "wire_words": tr.wire_bytes() / selection.BYTES_PER_WORD,
        "local_words": npp * math.log2(max(2, n)) + npp,
        "counts": tr.counts(),
        "wire_bytes": tr.wire_bytes(),
    }


_FEATURES = ("p2p", "fused", "hops", "wire_words", "local_words")


# ---------------------------------------------------------------------------
# Phase 1: primitive microbenchmarks → the machine profile
# ---------------------------------------------------------------------------


def _median_seconds(jitted, *args, iters=5):
    jax.block_until_ready(jitted(*args))          # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_ppermute(p: int, w: int, chain: int = 16) -> float:
    """Seconds per ppermute launch of a w-word/PE payload at axis size p."""
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(v):
        for _ in range(chain):
            v = comm.ppermute(v, "pe", perm) + 1  # +1 defeats CSE
        return v

    f = jax.jit(comm.sim_map(body, "pe", p))
    x = jnp.zeros((p, w), jnp.int32)
    return _median_seconds(f, x) / chain


def bench_all_gather(p: int, w: int, chain: int = 8) -> float:
    """Seconds per fused-collective launch (tiny all_gather) at size p."""

    def body(v):
        acc = v
        for _ in range(chain):
            g = comm.all_gather(acc, "pe", tiled=True)    # (p*w,)
            acc = g.reshape(p, w)[0] + 1                  # (w,), chained
        return acc

    f = jax.jit(comm.sim_map(body, "pe", p))
    x = jnp.zeros((p, w), jnp.int32)
    return _median_seconds(f, x) / chain


def _local_sort_seconds(p: int, m: int, kernel: bool = False) -> float:
    r = np.random.default_rng(0)
    if kernel:
        from repro.kernels.bitonic import local_sort_fast
        f = jax.jit(lambda v: local_sort_fast(v))
        x = jnp.asarray(r.integers(0, 2**32, size=m, dtype=np.int64)
                        .astype(np.uint32))
        return _median_seconds(f, x)
    f = jax.jit(comm.sim_map(lambda v: jnp.sort(v), "pe", p))
    x = jnp.asarray(r.integers(0, 2**31, size=(p, m), dtype=np.int64)
                    .astype(np.int32))
    return _median_seconds(f, x)


def bench_local_sort_rate(p: int, m: int = 1 << 14,
                          kernel: bool = False) -> float:
    """Local words/s in model units: per-PE sort of m words costs
    m·lg(m)/local_rate on the host that co-executes all p PEs.

    ``kernel=True`` times the Pallas bitonic path on one shard instead
    (interpret mode off-TPU — a machinery check, not silicon perf)."""
    return m * math.log2(m) / _local_sort_seconds(p, m, kernel)


def _partition_seconds(p: int, m: int, nb: int, kernel: bool = False) -> float:
    from repro.kernels.partition import partition_buckets
    r = np.random.default_rng(0)
    keys = np.sort(r.integers(0, 2**32, size=(p, m), dtype=np.int64)
                   .astype(np.uint32), axis=1)
    ties = r.integers(0, 2**32, size=(p, m), dtype=np.int64).astype(np.uint32)
    sk = jnp.asarray(np.sort(r.integers(0, 2**32, size=nb - 1, dtype=np.int64)
                             .astype(np.uint32)))
    st = jnp.asarray(np.zeros(nb - 1, np.uint32))

    def body(k, t):
        return partition_buckets(k, t, sk, st, n_buckets=nb,
                                 use_kernel=kernel)

    if kernel:
        f = jax.jit(body)
        return _median_seconds(f, jnp.asarray(keys[0]), jnp.asarray(ties[0]))
    f = jax.jit(comm.sim_map(body, "pe", p))
    return _median_seconds(f, jnp.asarray(keys), jnp.asarray(ties))


def bench_partition_rate(p: int, m: int = 1 << 14, nb: int = 64,
                         kernel: bool = False) -> float:
    """Partition words/s in model units: classify + rank + histogram of m
    locally-sorted words into nb buckets costs m·lg(nb)/partition_rate
    (the searchsorted depth — the fused kernel's branchless scan is
    O(m·nb) arithmetic but one memory pass, which is what the wall-clock
    actually tracks).  ``kernel=False`` times the jnp reference the sim
    backend runs, co-executing all p PEs like the other primitives;
    ``kernel=True`` times the fused Pallas kernel on one shard."""
    return m * math.log2(max(2, nb)) / _partition_seconds(p, m, nb, kernel)


# ---------------------------------------------------------------------------
# Two-tier (nested-axis) microbenchmarks: distinct inner/outer α, β
# ---------------------------------------------------------------------------


def bench_axis_ppermute(p_o: int, p_i: int, axis: str, w: int,
                        chain: int = 16) -> float:
    """Seconds per ppermute launch on ONE real axis of a nested
    (inter, intra) sim mesh — the per-axis analogue of
    :func:`bench_ppermute` (calls naming a real axis pass through the
    nested view unchanged)."""
    axes = (("inter", p_o), ("intra", p_i))
    size = p_o if axis == "inter" else p_i
    perm = [(i, (i + 1) % size) for i in range(size)]

    def body(v):
        for _ in range(chain):
            v = comm.ppermute(v, axis, perm) + 1  # +1 defeats CSE
        return v

    f = jax.jit(comm.sim_map(body, "sort", nested=axes))
    x = jnp.zeros((p_o, p_i, w), jnp.int32)
    return _median_seconds(f, x) / chain


def bench_axis_all_gather(p_o: int, p_i: int, axis: str, w: int,
                          chain: int = 8) -> float:
    """Seconds per fused-collective launch (tiny all_gather) on one real
    axis of a nested mesh."""
    axes = (("inter", p_o), ("intra", p_i))
    size = p_o if axis == "inter" else p_i

    def body(v):
        acc = v
        for _ in range(chain):
            g = comm.all_gather(acc, axis, tiled=True)    # (size*w,)
            acc = g.reshape(size, w)[0] + 1               # (w,), chained
        return acc

    f = jax.jit(comm.sim_map(body, "sort", nested=axes))
    x = jnp.zeros((p_o, p_i, w), jnp.int32)
    return _median_seconds(f, x) / chain


def measure_nested_profile(model: CostModel, p_o: int, p_i: int) -> CostModel:
    """Fit the *inner-axis* machine constants from per-axis primitives on
    a (p_o × p_i) nested sim mesh and attach them to ``model``.

    On the single-host sim backend both axes run at memory speed, so the
    inner/outer split mostly demonstrates the machinery; on a real
    inter-host × intra-host slice the same sweep separates NIC-bound from
    ICI-bound constants (the two-tier measurement of arXiv 1410.6754)."""
    import dataclasses as _dc
    w_lo, w_hi = 64, 4096
    prior = selection.DEFAULT_MODEL
    a_i = bench_axis_ppermute(p_o, p_i, "intra", 1)
    t_lo = bench_axis_ppermute(p_o, p_i, "intra", w_lo)
    t_hi = bench_axis_ppermute(p_o, p_i, "intra", w_hi)
    b_i = max((t_hi - t_lo) / (w_hi - w_lo), 1e-3 * prior.beta)
    ac_i = max(bench_axis_all_gather(p_o, p_i, "intra", 1),
               1e-3 * prior.alpha_c)
    a_o = bench_axis_ppermute(p_o, p_i, "inter", 1)
    ac_o = bench_axis_all_gather(p_o, p_i, "inter", 1)
    meta = dict(model.meta)
    meta["nested_microbench"] = {
        "mesh_shape": [p_o, p_i],
        "intra": {"alpha": a_i, "alpha_c": ac_i, "beta": b_i},
        "inter": {"alpha": a_o, "alpha_c": ac_o},
        "method": "per-axis primitives on the nested sim mesh "
                  "(two-tier 1410.6754-style)",
    }
    return _dc.replace(model, alpha_inner=float(a_i),
                       alpha_c_inner=float(ac_i), beta_inner=float(b_i),
                       meta=meta)


def run_nested_sweep(p_o: int, p_i: int, iters: int, exps=(0, 2, 4)):
    """Nested-vs-flat RAMS wall-clock cells at the same total p.

    Cells land in the bench JSON under algorithm ``rams@{p_o}x{p_i}``
    (nested) next to ``rams-flat@{p_o}x{p_i}`` (the flat-axis oracle run
    with the *same* aligned level schedule), so ``tools/check_bench.py``
    gates the hierarchical path's trajectory too.  Both labels carry the
    mesh shape: the plain ``rams`` cells of :func:`run_sweep` time the
    default schedule and must not be overwritten, and the ``@`` marker
    keeps all of these out of the crossover winner tables."""
    from repro.core.rams import nested_level_bits
    p = p_o * p_i
    bits = tuple(nested_level_bits(p_o, p_i))
    cells = []
    for e in exps:
        n = max(1, int(p * 2.0 ** e))
        x = generate_instance("Uniform", p, n, seed=11).astype(np.int32)
        for label, cfg, feat_kw in (
                (f"rams@{p_o}x{p_i}",
                 SortConfig(mesh_shape=(p_o, p_i), algorithm="rams",
                            backend="sim"),
                 {"mesh_shape": (p_o, p_i)}),
                (f"rams-flat@{p_o}x{p_i}",
                 SortConfig(p=p, algorithm="rams", backend="sim",
                            algo_kw={"level_bits": bits}),
                 {"level_bits": bits})):
            us = timeit(lambda: np.asarray(psort(x, config=cfg)),
                        warmup=1, iters=iters)
            feat = cell_features(n, p, "rams", **feat_kw)
            cell = {"p": p, "e": e, "n": n, "algorithm": label,
                    "us": us, "seconds": us * 1e-6, **feat}
            cells.append(cell)
            emit(f"calibrate/nested{p_o}x{p_i}/npp2^{e}/{label}", us,
                 f"p2p={feat['p2p']} fused={feat['fused']} "
                 f"wire={feat['wire_bytes']}B")
    return cells


def measure_profile(ps, name: str) -> CostModel:
    """Microbenchmark the five machine constants on the sim backend.

    All payload-bearing measurements run at the largest swept p: the sim
    host co-executes every emulated PE, so per-PE costs are p-dependent —
    the profile models the machine actually used for the sweep."""
    pmax = max(ps)
    w_lo, w_hi = 64, 4096
    alpha = bench_ppermute(pmax, 1)
    t_lo, t_hi = bench_ppermute(pmax, w_lo), bench_ppermute(pmax, w_hi)
    beta = max((t_hi - t_lo) / (w_hi - w_lo), 1e-3 * selection.DEFAULT_MODEL.beta)

    hops = np.array([float(p) ** (1.0 / 3.0) for p in ps])
    t_coll = np.array([bench_all_gather(p, 1) for p in ps])
    prior = selection.DEFAULT_MODEL
    if len(ps) >= 2:
        slope, intercept = np.polyfit(hops, t_coll, 1)
        alpha_hop = max(float(slope), 1e-3 * prior.alpha_hop)
        alpha_c = max(float(intercept), 1e-3 * prior.alpha_c)
    else:
        alpha_hop = prior.alpha_hop
        alpha_c = max(float(t_coll[0]) - alpha_hop * float(hops[0]),
                      1e-3 * prior.alpha_c)
    local_rate = bench_local_sort_rate(pmax)
    partition_rate = bench_partition_rate(pmax)
    io_beta = bench_io_rate()
    overlap_io = measure_overlap()
    overlap_stream = measure_stream_overlap()
    # the model has one overlap knob shared by the external (io) and
    # in-core (wire) discounts; fit it from the larger demonstrated hiding
    # so a backend that overlaps either lane gets credit — on CPU sim both
    # measure ~0 and the β terms stay undiscounted
    overlap = max(overlap_io, overlap_stream)
    # kernel variants run in interpret mode off-TPU: one small shard each,
    # recorded for the bench trajectory (not used as profile constants)
    sort_kernel_rate = bench_local_sort_rate(1, m=1 << 11, kernel=True)
    partition_kernel_rate = bench_partition_rate(1, m=1 << 12, kernel=True)
    return CostModel(
        name=name,
        alpha=float(alpha), alpha_c=float(alpha_c),
        alpha_hop=float(alpha_hop), beta=float(beta),
        local_rate=float(local_rate),
        partition_rate=float(partition_rate),
        slot_overhead=prior.slot_overhead,
        io_beta=float(io_beta), overlap=float(overlap),
        meta={
            "microbench": {
                "method": "primitive microbenchmarks (arXiv 1410.6754 style)",
                "p": list(ps), "p_payload": pmax,
                "ppermute_s": {"w1": alpha, f"w{w_lo}": t_lo, f"w{w_hi}": t_hi},
                "all_gather_s": {str(p): float(t) for p, t in zip(ps, t_coll)},
                "local_sort_words_s": float(local_rate),
                "local_sort_kernel_words_s": float(sort_kernel_rate),
                "partition_words_s": float(partition_rate),
                "partition_kernel_words_s": float(partition_kernel_rate),
                "io_s_word": float(io_beta),
                "overlap_fraction": float(overlap),
                "overlap_io_fraction": float(overlap_io),
                "overlap_stream_fraction": float(overlap_stream),
                "host": platform.node(),
                "backend": "sim",
            },
        })


def fit_profile(cells, name: str) -> CostModel:
    """Non-negative least squares of the 5-parameter machine profile over
    measured (features, seconds) cells.  Parameters the data cannot
    identify (zero weight) fall back to a small fraction of the prior so
    the regime structure stays non-degenerate."""
    A = np.array([[c[f] for f in _FEATURES] for c in cells], float)
    t = np.array([c["seconds"] for c in cells], float)
    try:
        from scipy.optimize import nnls
        theta, _ = nnls(A, t)
    except Exception:                     # scipy-less fallback
        theta, *_ = np.linalg.lstsq(A, t, rcond=None)
        theta = np.clip(theta, 0.0, None)
    pred = A @ theta
    ss_res = float(np.sum((t - pred) ** 2))
    ss_tot = float(np.sum((t - t.mean()) ** 2)) or 1.0
    r2 = 1.0 - ss_res / ss_tot

    prior = selection.DEFAULT_MODEL
    floors = (prior.alpha, prior.alpha_c, prior.alpha_hop, prior.beta,
              1.0 / prior.local_rate)
    alpha, alpha_c, alpha_hop, beta, inv_rate = (
        max(v, 1e-3 * f) for v, f in zip(theta, floors))
    return CostModel(
        name=name,
        alpha=alpha, alpha_c=alpha_c, alpha_hop=alpha_hop, beta=beta,
        local_rate=1.0 / inv_rate,
        slot_overhead=prior.slot_overhead,
        meta={
            "fit": {
                "r2": r2,
                "theta": [float(v) for v in theta],
                "features": list(_FEATURES),
                "n_cells": len(cells),
                "host": platform.node(),
                "backend": "sim",
            },
        })


def _winner_sequence(rows):
    """[(e, winner)] → [(e, prev, new)] transition list."""
    out, prev = [], None
    for e, w in rows:
        if w != prev and prev is not None:
            out.append((e, prev, w))
        prev = w
    return out


def measured_crossovers(cells, p: int):
    by_e = {}
    for c in cells:
        if c["p"] != p or "@" in c["algorithm"]:   # skip nested-mesh cells
            continue
        by_e.setdefault(c["e"], []).append((c["seconds"], c["algorithm"]))
    rows = [(e, min(v)[1]) for e, v in sorted(by_e.items())]
    return rows, _winner_sequence(rows)


def predicted_crossovers(p: int, exps, model: CostModel):
    rows = [(e, selection.select_algorithm(max(1, int(p * 2.0 ** e)), p,
                                           model=model)) for e in sorted(exps)]
    return rows, _winner_sequence(rows)


def run_sweep(ps, exps_override, iters: int):
    cells = []
    for p in ps:
        exps = exps_override or EXPS.get(p, EXPS[256])
        seen = set()
        for e in exps:
            n = max(1, int(p * 2.0 ** e))
            for algo in ALGOS:
                if not eligible(algo, e, p) or (algo, n) in seen:
                    continue
                seen.add((algo, n))
                x = generate_instance("Uniform", p, n, seed=11).astype(np.int32)
                cfg = SortConfig(p=p, algorithm=algo, backend="sim")
                us = timeit(lambda: np.asarray(psort(x, config=cfg)),
                            warmup=1, iters=iters)
                feat = cell_features(n, p, algo)
                cell = {"p": p, "e": e, "n": n, "algorithm": algo,
                        "us": us, "seconds": us * 1e-6, **feat}
                cells.append(cell)
                emit(f"calibrate/p{p}/npp2^{e}/{algo}", us,
                     f"p2p={feat['p2p']} fused={feat['fused']} "
                     f"wire={feat['wire_bytes']}B")
    return cells


def run_local_bench(pmax: int):
    """Local-phase wall-clock cells (sort vs partition, jnp vs Pallas
    kernel) for the CI trajectory gate.  They carry no counted-trace
    features, so they merge into the JSON's ``bench`` mapping only —
    never into the NNLS fit cells.  The ``p`` key labels the sweep's
    pmax for stable cell addressing (the kernel variants time one shard
    in interpret mode); ``e`` is log2 of the per-shard word count."""
    rows = []
    for label, m, kernel in (("local/sort_rate", 1 << 14, False),
                             ("local/sort_kernel", 1 << 11, True),
                             ("local/partition_rate", 1 << 14, False),
                             ("local/partition_kernel", 1 << 12, True)):
        p_run = 1 if kernel else pmax
        if label.startswith("local/sort"):
            t = _local_sort_seconds(p_run, m, kernel=kernel)
        else:
            t = _partition_seconds(p_run, m, 64, kernel=kernel)
        us = t * 1e6
        rows.append({"p": pmax, "e": int(math.log2(m)),
                     "algorithm": label, "us": us})
        emit(f"calibrate/{label}", us, f"m=2^{int(math.log2(m))}")
    return rows


def bench_io_rate(m: int = 1 << 18, iters: int = 5) -> float:
    """Host↔device streaming seconds per 32-bit word (``CostModel.io_beta``):
    a device_put + device_get round-trip of an m-word buffer, halved.  On
    the CPU sim backend this is a memcpy pair — the measurement matters on
    accelerators, where it is the external lane's PCIe term."""
    x = np.zeros(m, np.int32)
    ts = []
    jax.block_until_ready(jax.device_put(x))          # warm the path
    for _ in range(iters):
        t0 = time.perf_counter()
        np.asarray(jax.block_until_ready(jax.device_put(x)))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) / (2 * m)


def _form_runs_seconds(m: int, budget: int, double_buffer: bool) -> float:
    from repro.core import external as ext
    r = np.random.default_rng(0)
    keys = r.integers(0, 2**32, size=m, dtype=np.int64).astype(np.uint32)
    idx = np.arange(m, dtype=np.uint32)
    ext.form_runs(keys, idx, budget=budget,
                  double_buffer=double_buffer)        # compile + warm
    t0 = time.perf_counter()
    ext.form_runs(keys, idx, budget=budget, double_buffer=double_buffer)
    return time.perf_counter() - t0


def measure_overlap(m: int = 1 << 16, budget: int = 1 << 13) -> float:
    """``CostModel.overlap``: the fraction of run-formation wall-clock the
    double-buffered copies hide, measured as 1 - t(db)/t(serial), clamped
    to [0, 1).  ~0 on the synchronous CPU sim backend; meaningful where
    device_put is truly async."""
    t_serial = _form_runs_seconds(m, budget, double_buffer=False)
    t_db = _form_runs_seconds(m, budget, double_buffer=True)
    return float(min(0.99, max(0.0, 1.0 - t_db / max(t_serial, 1e-12))))


def _stream_exchange_seconds(p: int, w: int) -> float:
    """One chunk-granular slotted exchange (``comm.alltoall_stream`` with a
    staging fold) of p·w words/PE on the sim backend."""
    def body(v):
        def fold(acc, chunk, src):
            return jax.lax.dynamic_update_slice(
                acc, chunk.reshape(1, w), (src.astype(jnp.int32),
                                           jnp.int32(0)))
        init = jnp.zeros((p, w), jnp.int32)
        return comm.alltoall_stream(v, "pe", fold, init, p)

    f = jax.jit(comm.sim_map(body, "pe", p))
    x = jnp.zeros((p, p * w), jnp.int32)
    return _median_seconds(f, x)


def _overlap_pair_us(p: int = 8, e: int = 8, algo: str = "rams",
                     iters: int = 2):
    """(barrier µs, streamed µs) of the same in-core psort cell — the
    pipelined exchange+merge (``overlap=True``) against the barrier path it
    is bitwise-equal to."""
    n = p << e
    x = generate_instance("Uniform", p, n, seed=11).astype(np.int32)
    cfg = SortConfig(p=p, algorithm=algo, backend="sim")
    us_b = timeit(lambda: np.asarray(psort(x, config=cfg)),
                  warmup=1, iters=iters)
    us_s = timeit(lambda: np.asarray(
        psort(x, config=cfg.replace(overlap=True))), warmup=1, iters=iters)
    return us_b, us_s


def measure_stream_overlap(p: int = 8, e: int = 8) -> float:
    """In-core counterpart of :func:`measure_overlap`: the fraction of the
    in-core exchange+merge the chunk-granular pipeline hides, measured
    end-to-end as 1 - t(streamed)/t(barrier), clamped to [0, 1).

    On the synchronous CPU sim backend nothing actually overlaps — the
    per-chunk local sorts and the k-way merge tree are exposed work on top
    of the same wire traffic — so the streamed path measures *slower* and
    this clamps to 0, keeping ``CostModel.overlap`` honest: the model only
    discounts the β terms where the machine demonstrably hides them."""
    us_b, us_s = _overlap_pair_us(p=p, e=e)
    return float(min(0.99, max(0.0, 1.0 - us_s / max(us_b, 1e-9))))


def run_overlap_bench(pmax: int):
    """Exchange/merge-overlap wall-clock cells for the CI trajectory gate,
    in the ``run_local_bench`` shape (no counted-trace features):

      * ``overlap/stream_rate``  — one chunk-granular slotted exchange
        (p = 8, 2^10 words per destination) with a staging fold;
      * ``overlap/e2e``          — streamed in-core ``psort(overlap=True)``
        at p = 8, n/p = 2^8 (rams);
      * ``overlap/e2e_barrier``  — the barrier path of the identical cell,
        so the gate tracks both trajectories and the exposed-pipeline
        ratio on CPU sim stays visible in the artifact.
    """
    rows = []
    p, w = 8, 1 << 10
    us = _stream_exchange_seconds(p, w) * 1e6
    rows.append({"p": pmax, "e": int(math.log2(w)),
                 "algorithm": "overlap/stream_rate", "us": us})
    emit("calibrate/overlap/stream_rate", us, f"p={p} w=2^{int(math.log2(w))}")

    e = 8
    us_b, us_s = _overlap_pair_us(p=p, e=e)
    rows.append({"p": pmax, "e": e, "algorithm": "overlap/e2e", "us": us_s})
    rows.append({"p": pmax, "e": e, "algorithm": "overlap/e2e_barrier",
                 "us": us_b})
    ratio = us_s / max(us_b, 1e-9)
    emit("calibrate/overlap/e2e", us_s,
         f"p={p} n/p=2^{e} rams streamed (barrier {us_b:.0f}us, "
         f"ratio {ratio:.2f})")
    return rows


def run_external_bench(pmax: int):
    """External-lane wall-clock cells for the CI trajectory gate, in the
    ``run_local_bench`` shape (no counted-trace features — they join the
    JSON's ``bench`` mapping only):

      * ``external/run_formation`` — pass A, 2^14 words through a 2^11
        budget (8 double-buffered device round-trips);
      * ``external/kway_merge`` — pass D, classifier engine over the 8
        formed runs;
      * ``external/e2e`` — the full four-pass ``psort(external=...)`` at
        p = 8, n/p = 2^8, budget 2^6 (4 runs/PE).
    """
    from repro.core import external as ext
    from repro.core.external import ExternalPolicy
    rows = []
    m, budget = 1 << 14, 1 << 11
    r = np.random.default_rng(0)
    keys = r.integers(0, 2**32, size=m, dtype=np.int64).astype(np.uint32)
    idx = np.arange(m, dtype=np.uint32)

    us = timeit(lambda: ext.form_runs(keys, idx, budget=budget),
                warmup=1, iters=2)
    rows.append({"p": pmax, "e": int(math.log2(m)),
                 "algorithm": "external/run_formation", "us": us})
    emit("calibrate/external/run_formation", us,
         f"m=2^{int(math.log2(m))} budget=2^{int(math.log2(budget))}")

    runs = ext.form_runs(keys, idx, budget=budget)
    us = timeit(lambda: ext.merge_runs(runs, budget=budget),
                warmup=1, iters=2)
    rows.append({"p": pmax, "e": int(math.log2(m)),
                 "algorithm": "external/kway_merge", "us": us})
    emit("calibrate/external/kway_merge", us, f"runs={len(runs)}")

    p, e = 8, 8
    n = p << e
    x = generate_instance("Uniform", p, n, seed=11).astype(np.int32)
    cfg = SortConfig(p=p, backend="sim", external=ExternalPolicy(budget=1 << 6))
    us = timeit(lambda: np.asarray(psort(x, config=cfg)), warmup=1, iters=2)
    rows.append({"p": pmax, "e": e, "algorithm": "external/e2e", "us": us})
    emit("calibrate/external/e2e", us,
         f"p={p} n/p=2^{e} budget=2^6 runs=4")
    return rows


EXTERNAL_GRID = ((256, 4, 16), (256, 4, 32), (1024, 8, 32), (1024, 8, 64))


def external_rows():
    """The "External memory" grid: per-pass counted traces of the
    out-of-core lane (``trace_collectives(external=...)`` — seeded input,
    trace-time counts, no wall-clock, so ``tools/check_docs.py`` can diff
    the regenerated file).  The point of the grid: wire volume is paid
    once per run pass (R slotted all_to_alls) while the host↔device
    stream (io bytes) covers every element twice — run formation and
    merge — independent of R."""
    from repro.core.external import ExternalPolicy
    rows = []
    for n, p, budget in EXTERNAL_GRID:
        tr = trace_collectives(n, SortConfig(
            p=p, external=ExternalPolicy(budget=budget)))
        per = -(-n // p)
        runs = -(-per // budget)
        passes = sum(1 for t in tr.tags() if t.startswith("ext:pass"))
        a2a = tr.filter(primitive="all_to_all")
        rows.append((n, p, budget, runs, passes, a2a.counts()["all_to_all"],
                     tr.wire_bytes(), tr.io_bytes(),
                     tr.filter(tag="ext:runs").io_bytes(),
                     tr.filter(tag="ext:merge").io_bytes()))
    return rows


SUBGROUP_PS = (4, 16, 64)
SUBGROUP_DS = (1, 2, 4)

NESTED_GRID = ((2, 8), (4, 16), (16, 64))


def nested_rows(npp: int = 16):
    """The "Hierarchical mesh" grid: per-PE counted traces of nested RAMS
    over (p_outer × p_inner) sim meshes, split by real axis.

    Deterministic (trace-time counts, no wall-clock), so
    ``tools/check_docs.py`` can diff the regenerated file.  The point of
    the grid: the slow *inter* axis carries the shuffle plus exactly one
    level's all_to_all — every later level is intra-only, so inter-axis
    volume stays flat as levels deepen."""
    rows = []
    for p_o, p_i in NESTED_GRID:
        p = p_o * p_i
        n = npp * p
        tr = trace_collectives(n, SortConfig(mesh_shape=(p_o, p_i),
                                             algorithm="rams"))
        ax = tr.by_axis()
        inter_a2a = tr.filter(primitive="all_to_all", axis="inter")
        rows.append((p_o, p_i, n, len(tr.tags()) - 1,
                     ax["inter"]["launches"], ax["inter"]["wire_bytes"],
                     ax["intra"]["launches"], ax["intra"]["wire_bytes"],
                     " ".join(inter_a2a.tags())))
    return rows


def subgroup_rows(model: CostModel, npp: int = 32):
    """The "Subgroup sort" grid: per-PE counted collective traces of the
    auto-selected algorithm (under ``model``) over (d, p_sort) sim meshes.

    Deterministic (``trace_collectives`` counts at trace time, no
    wall-clock), so ``tools/check_docs.py`` can diff the regenerated file.
    The point of the grid: the per-PE trace is **independent of d** —
    every collective resolves relative to the sort axis, so adding data
    rows multiplies tenants, not per-PE communication.
    """
    rows = []
    for p in SUBGROUP_PS:
        n = npp * p
        algo = selection.select_algorithm(n, p, model=model)
        for d in SUBGROUP_DS:
            tr = trace_collectives(n, SortConfig(p=p, algorithm=algo), d=d)
            rows.append((p, d, n, algo, tr.p2p_launches, tr.fused_launches,
                         tr.wire_bytes()))
    return rows


QUERY_GRID_P = (8, 64, 256)
QUERY_GRID = (("rank_of_key", 32, None), ("range_query", 32, None),
              ("percentile", 32, None), ("percentile", 64, None),
              ("top_k", 32, 16), ("sort", 32, None))


def query_rows(npp: int = 1 << 14, batch: int = 8):
    """The "Query serving" grid: per-PE counted traces of the selection
    fast paths (``core/queries.py``) next to the full sort that would
    otherwise answer the same micro-batch.

    Deterministic (trace-time counts, no wall-clock), so
    ``tools/check_docs.py`` can diff the regenerated file.  The point of
    the grid: a selection query's launch count is fixed by the key width
    (``ceil(bits/4)`` refinement rounds) and its wire volume by the batch
    — both independent of n — while the sort's volume is Ω(n/p)."""
    from repro.core.queries import trace_query
    rows = []
    for p in QUERY_GRID_P:
        n = npp * p
        for kind, bits, k in QUERY_GRID:
            dtype = np.uint32 if bits == 32 else np.uint64
            tr = trace_query(kind, n, p, batch=batch, dtype=dtype, k=k)
            rows.append((p, n, kind, bits, tr.p2p_launches,
                         tr.fused_launches, tr.wire_bytes()))
    return rows


def write_experiments(path: str, model: CostModel):
    """Regenerate EXPERIMENTS.md: the regime tables ``selection.py``'s
    docstring points at, the subgroup-sort grid, and the profile-JSON
    schema, under the given machine profile."""
    lines = [
        "# EXPERIMENTS",
        "",
        "Regime tables of `repro.core.selection.select_algorithm` — which",
        "algorithm the α/β cost model picks per (n/p, p).  Regenerate after",
        "recalibration with:",
        "",
        "```sh",
        "PYTHONPATH=src python benchmarks/calibrate.py --experiments-only \\",
        "    [--profile profiles/<machine>.json]",
        "```",
        "",
        "(CI's docs job diffs this file against the regenerated output —",
        "edit by rerunning the command, not by hand.)",
        "",
        f"Machine profile: **{model.name}** "
        f"(α={model.alpha:.3g}s, α_c={model.alpha_c:.3g}s, "
        f"α_hop={model.alpha_hop:.3g}s, β={model.beta:.3g}s/word, "
        f"local={model.local_rate:.3g}w/s, "
        f"partition={model.part_rate:.3g}w/s)",
        "",
    ]
    for p in (64, 1024, 262144):
        lines += [f"## p = {p}", "", "| log2(n/p) | n | algorithm |",
                  "|---:|---:|---|"]
        for e, n, algo in selection.regime_table(p, range(-8, 24, 2),
                                                 model=model):
            lines.append(f"| {e} | {n} | {algo} |")
        rows = [(e, a) for e, _, a in
                selection.regime_table(p, range(-8, 24), model=model)]
        seq = " → ".join([rows[0][1]] + [w for _, _, w in
                                         _winner_sequence(rows)])
        lines += ["", f"Regime sequence: {seq}", ""]

    lines += [
        "## Subgroup sort (p_sort × d)",
        "",
        "Batched `psort` over a (d, p_sort) mesh sorts each of the d rows",
        "within its own sort-axis subgroup (`backend=\"sim\"` shown; the",
        "shard_map path shards the same body over a 2-D device mesh).  The",
        "cells are the **per-PE counted collective traces**",
        "(`repro.core.api.trace_collectives(n, p, algo, d=d)`) of the",
        "auto-selected algorithm at n/p = 32: identical down the d column",
        "because every collective resolves relative to the named sort axis",
        "— data-axis rows are isolated tenants, adding rows adds zero",
        "per-PE communication.",
        "",
        "| p_sort | d | n (per row) | algorithm | p2p launches "
        "| fused launches | wire bytes/PE |",
        "|---:|---:|---:|---|---:|---:|---:|",
    ]
    for p, dd, n, algo, p2p, fused, wire in subgroup_rows(model):
        lines.append(f"| {p} | {dd} | {n} | {algo} | {p2p} | {fused} "
                     f"| {wire} |")

    lines += [
        "",
        "## Hierarchical mesh (p_outer × p_inner)",
        "",
        "Nested-axis RAMS (`psort(mesh_shape=(p_outer, p_inner))`) maps the",
        "level schedule onto a hierarchical (inter × intra) mesh: the first",
        "level splits the data across the slow *inter* axis, every later",
        "level recurses inside an *intra* subcube",
        "(`repro.core.comm.NestedCollectives` decomposes the virtual-axis",
        "collectives; `repro.core.rams.nested_level_bits` aligns the",
        "schedule).  Cells are per-PE counted traces",
        "(`trace_collectives(n, mesh_shape=..., algorithm=\"rams\")`, n/p =",
        "16) split by real axis — the inter column carries only the initial",
        "shuffle plus **one** level's all_to_all, independent of depth,",
        "while the run stays bitwise-identical to the flat path.",
        "",
        "| p_outer | p_inner | n | levels | inter launches | inter bytes/PE "
        "| intra launches | intra bytes/PE | inter a2a phases |",
        "|---:|---:|---:|---:|---:|---:|---:|---:|---|",
    ]
    for p_o, p_i, n, lvls, il, ib, al, ab, tags in nested_rows():
        lines.append(f"| {p_o} | {p_i} | {n} | {lvls} | {il} | {ib} "
                     f"| {al} | {ab} | {tags} |")

    lines += [
        "",
        "## External memory (out-of-core)",
        "",
        "`psort(external=ExternalPolicy(budget=...))` streams shards larger",
        "than the device budget through run formation + k-way merge",
        "(docs/ARCHITECTURE.md \"External memory\").  Cells are per-pass",
        "counted traces (`trace_collectives(n, p, external=...)`, seeded",
        "deterministic input): R = ceil(n/p / budget) slotted all_to_all",
        "passes carry the wire volume, while the host↔device stream (the",
        "`ext:h2d`/`ext:d2h` pseudo-events, `CommTrace.io_bytes()`) covers",
        "every element once in each direction per streaming pass —",
        "run formation and merge — independent of R.",
        "",
        "| n | p | budget | runs/PE | a2a passes | a2a launches/PE "
        "| wire bytes/PE | io bytes | io: runs | io: merge |",
        "|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for (n, p, budget, runs, passes, a2a, wire, io_b, io_r,
         io_m) in external_rows():
        lines.append(f"| {n} | {p} | {budget} | {runs} | {passes} | {a2a} "
                     f"| {wire} | {io_b} | {io_r} | {io_m} |")

    lines += [
        "",
        "## Query serving (selection fast paths vs. full sort)",
        "",
        "`launch/sort_serve.py` micro-batches queued queries by kind and",
        "answers each batch with one launch of a `core/queries.py`",
        "primitive over the resident (p, cap) locally-sorted shards — a",
        "batch is a barrier, so every request in it shares the device",
        "latency.  Counting queries (`rank_of_key`, `range_query`) cost one",
        "fused psum; order statistics (`percentile`, `top_k`) run the exact",
        "rank selection — a §III-B butterfly rank window (log2 p p2p steps,",
        "32-bit keys only) then `ceil(bits/4)` counting-verified refinement",
        "rounds of one sketch all_gather + one count psum, plus a verify",
        "psum.  Cells are per-PE counted traces (`trace_query(kind, n, p,",
        "batch=8)`, n/p = 2^14): the selection columns are fixed by the key",
        "width and batch — independent of n — while the full sort's wire",
        "volume is Ω(n/p).  `select_algorithm(n, p, query=...)` encodes the",
        "crossover (`cost_select`): full sort wins only on tiny instances.",
        "",
        "| p | n | query | key bits | p2p launches | fused launches "
        "| wire bytes/PE |",
        "|---:|---:|---|---:|---:|---:|---:|",
    ]
    for p, n, kind, bits, p2p, fused, wire in query_rows():
        lines.append(f"| {p} | {n} | {kind} | {bits} | {p2p} | {fused} "
                     f"| {wire} |")

    lines += [
        "",
        "## `profiles/*.json` schema",
        "",
        "A profile is one serialized `repro.core.selection.CostModel`",
        "(`CostModel.load(path)` / `model.save(path)` round-trip):",
        "",
        "| field | type | meaning |",
        "|---|---|---|",
        "| `name` | str | profile id, conventionally `<os>-<arch>-<backend>` |",
        "| `alpha` | float s | per point-to-point step "
        "(collective-permute launch + link latency) |",
        "| `alpha_c` | float s | per fused-collective launch "
        "(all_gather / psum / all_to_all) |",
        "| `alpha_hop` | float s | per torus hop; fused collectives are "
        "charged `alpha_hop · p^(1/3)` pipeline fill |",
        "| `beta` | float s/word | per 32-bit word on the wire |",
        "| `local_rate` | float words/s | local sort/merge throughput |",
        "| `partition_rate` | float words/s / null | splitter-partition "
        "(classify + rank + histogram) throughput; null in profiles that "
        "predate the fused partition kernel → falls back to `local_rate` |",
        "| `slot_overhead` | float | static slot provisioning factor of "
        "the a2a exchanges |",
        "| `alpha_inner` | float s / null | intra-axis p2p step of a "
        "nested mesh (null = same as `alpha`) |",
        "| `alpha_c_inner` | float s / null | intra-axis fused-collective "
        "launch; intra levels pay no `alpha_hop` fill |",
        "| `beta_inner` | float s/word / null | intra-axis per-word cost "
        "(`--nested` two-tier fit) |",
        "| `io_beta` | float s/word / null | host↔device streaming cost of "
        "the external lane (null = PCIe-class prior via `io_b`) |",
        "| `overlap` | float | fraction of host↔device traffic hidden by "
        "the double-buffered copies (0 = exposed, 1 = hidden) |",
        "| `meta` | object | free-form provenance — `microbench` (the "
        "primitive measurements the constants came from), `sweep_fit` "
        "(whole-program NNLS diagnostic: `r2`, `theta`, `features`, "
        "`n_cells`, host, backend) |",
        "",
        "Profiles are **measured, not hand-edited**: "
        "`benchmarks/calibrate.py` writes them from primitive",
        "microbenchmarks (phase 1) and stashes the sweep regression in "
        "`meta` (phase 2); unknown top-level fields are rejected at load.",
        "",
    ]
    with open(path, "w") as f:
        f.write("\n".join(lines))
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--p", type=int, nargs="+", default=[64, 256],
                    help="emulated PE counts to sweep (powers of two)")
    ap.add_argument("--exps", type=int, nargs="+", default=None,
                    help="override log2(n/p) grid for every p")
    ap.add_argument("--fast", action="store_true",
                    help=f"thin grid {EXPS_FAST} (smoke runs)")
    ap.add_argument("--iters", type=int, default=2,
                    help="timed iterations per cell (after 1 warmup)")
    ap.add_argument("--nested", type=int, nargs=2, default=None,
                    metavar=("P_OUTER", "P_INNER"),
                    help="two-tier pass on a nested (inter × intra) sim "
                         "mesh: per-axis microbench fits distinct "
                         "inner/outer α, β into the profile, and a "
                         "nested-vs-flat RAMS sweep adds rams@PoxPi cells")
    ap.add_argument("--machine", default=None,
                    help="profile name (default <os>-<arch>-sim)")
    ap.add_argument("--profile-dir", default="profiles")
    ap.add_argument("--profile", default=None,
                    help="existing profile JSON (for --experiments-only)")
    ap.add_argument("--bench-json", default="BENCH_calibrate.json")
    ap.add_argument("--experiments", nargs="?", const="EXPERIMENTS.md",
                    default=None, help="also regenerate EXPERIMENTS.md")
    ap.add_argument("--experiments-only", action="store_true",
                    help="skip the sweep; only write EXPERIMENTS.md")
    args = ap.parse_args(argv)

    if args.experiments_only:
        model = CostModel.load(args.profile) if args.profile \
            else selection.DEFAULT_MODEL
        path = write_experiments(args.experiments or "EXPERIMENTS.md", model)
        print(f"# wrote {path} (profile: {model.name})")
        return 0

    machine = args.machine or \
        f"{platform.system().lower()}-{platform.machine()}-sim"
    exps_override = EXPS_FAST if args.fast else args.exps

    print("name,us_per_call,derived")
    model = measure_profile(args.p, machine)
    print(f"# microbenched profile: α={model.alpha:.3g}  "
          f"α_c={model.alpha_c:.3g}  α_hop={model.alpha_hop:.3g}  "
          f"β={model.beta:.3g}  local_rate={model.local_rate:.3g}  "
          f"partition_rate={model.part_rate:.3g}")
    if args.nested:
        p_o, p_i = args.nested
        model = measure_nested_profile(model, p_o, p_i)
        print(f"# two-tier ({p_o}x{p_i}): α_in={model.alpha_inner:.3g}  "
              f"α_c_in={model.alpha_c_inner:.3g}  "
              f"β_in={model.beta_inner:.3g}")

    cells = run_sweep(args.p, exps_override, args.iters)
    if args.nested:
        cells += run_nested_sweep(p_o, p_i, args.iters,
                                  exps=tuple(EXPS_FAST) if args.fast
                                  else (0, 2, 4))
    local_cells = run_local_bench(max(args.p))
    local_cells += run_external_bench(max(args.p))
    local_cells += run_overlap_bench(max(args.p))
    # whole-program regression over the sweep — diagnostic only (see
    # module docstring); kept in meta so the two views can be compared
    sweep_fit = fit_profile(cells, machine)
    model.meta["sweep_fit"] = {
        **sweep_fit.meta["fit"],
        "alpha": sweep_fit.alpha, "alpha_c": sweep_fit.alpha_c,
        "alpha_hop": sweep_fit.alpha_hop, "beta": sweep_fit.beta,
        "local_rate": sweep_fit.local_rate,
    }
    profile_path = model.save(os.path.join(args.profile_dir,
                                           f"{machine}.json"))
    r2 = sweep_fit.meta["fit"]["r2"]
    print(f"# wrote {profile_path}  (sweep-regression diagnostic R²={r2:.3f})")

    # --- predicted vs measured crossovers (Fig. 1 analogue) ---------------
    crossings = {}
    for p in args.p:
        exps = exps_override or EXPS.get(p, EXPS[256])
        meas_rows, meas_x = measured_crossovers(cells, p)
        pred_rows, pred_x = predicted_crossovers(p, exps, model)
        crossings[str(p)] = {
            "measured_winners": meas_rows, "measured_crossovers": meas_x,
            "predicted_winners": pred_rows, "predicted_crossovers": pred_x,
        }
        print(f"# p={p} measured : " +
              " ".join(f"2^{e}:{w}" for e, w in meas_rows))
        print(f"# p={p} predicted: " +
              " ".join(f"2^{e}:{w}" for e, w in pred_rows))

    bench = {}
    for c in cells + local_cells:
        bench.setdefault(str(c["p"]), {}).setdefault(
            c["algorithm"], {})[str(c["e"])] = c["us"]
    with open(args.bench_json, "w") as f:
        json.dump({
            "machine": machine,
            "host": platform.node(),
            "p": args.p,
            "cells": cells,
            "profile": {"path": profile_path,
                        "alpha": model.alpha, "alpha_c": model.alpha_c,
                        "alpha_hop": model.alpha_hop, "beta": model.beta,
                        "local_rate": model.local_rate,
                        "partition_rate": model.partition_rate,
                        "alpha_inner": model.alpha_inner,
                        "alpha_c_inner": model.alpha_c_inner,
                        "beta_inner": model.beta_inner,
                        "io_beta": model.io_beta,
                        "overlap": model.overlap},
            "sweep_fit": model.meta["sweep_fit"],
            "crossovers": crossings,
            "bench": bench,
        }, f, indent=2, sort_keys=True)
    print(f"# wrote {args.bench_json}")

    if args.experiments:
        path = write_experiments(args.experiments, model)
        print(f"# wrote {path} (profile: {model.name})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
