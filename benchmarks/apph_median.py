"""Paper App. H / Fig. 4: median-approximation quality, binary k-window
tree (§III-B, ours) vs Dean et al.'s ternary median tree.

2000 trials per size; reports max and variance of the rank error
|r/(n-1) - 1/2| and the fitted c·n^(-γ) envelope exponent.  The paper
finds binary ≈ 1.44·n^-0.39 beating ternary ≈ 2·n^-0.37.
"""
import numpy as np

from common import emit

TRIALS = 2000
K = 16


def binary_tree_median(x, k=K, rng=None):
    """k-window reduction over a balanced binary tree (paper §III-B with
    single-element leaves, the n = p setting of App. H) — vectorized."""
    n = len(x)
    m = 2 ** int(np.floor(np.log2(n)))
    vals = x[:m]
    # m=1 per leaf is odd: the paper's coin flip chooses floor/ceil centering
    # (without it the ±inf fillers drift systematically through the merges)
    coin = rng.integers(0, 2, size=m) if rng is not None \
        else np.zeros(m, np.int64)
    pos = k // 2 - 1 + coin                     # real element's slot
    cols = np.arange(k)[None, :]
    W = np.where(cols < pos[:, None], -np.inf,
                 np.where(cols == pos[:, None], vals[:, None], np.inf))
    while W.shape[0] > 1:
        pairs = W.reshape(-1, 2 * k)
        pairs = np.sort(pairs, axis=1)
        W = pairs[:, k // 2: k // 2 + k]        # middle k of each merge
    coin = int(rng.integers(2)) if rng is not None else 0
    w = W[0]
    v = w[k // 2 - 1 + coin]
    if not np.isfinite(v):                      # coin hit a filler
        v = w[k // 2 - coin]
    return v


def ternary_tree_median(x, rng):
    """Dean et al.: median-of-3 tournament tree."""
    vals = x.copy()
    rng.shuffle(vals)
    m = 3 ** int(np.floor(np.log(len(vals)) / np.log(3)))
    vals = vals[:m]
    while len(vals) > 1:
        vals = np.median(vals.reshape(-1, 3), axis=1)
    return vals[0]


def main():
    rng = np.random.default_rng(0)
    for bits in [8, 10, 12, 14]:
        n = 2 ** bits
        errs_b, errs_t = [], []
        for _ in range(TRIALS // 4):
            x = rng.integers(0, 2**32, size=n).astype(np.float64)
            for est, errs in ((binary_tree_median, errs_b),
                              (ternary_tree_median, errs_t)):
                v = est(x, rng=rng) if est is binary_tree_median \
                    else est(x, rng)
                r = np.searchsorted(np.sort(x), v)
                errs.append(abs(r / (n - 1) - 0.5))
        eb, et = np.array(errs_b), np.array(errs_t)
        emit(f"apph/binary/n{n}", 0.0,
             f"maxerr={eb.max():.4f} var={eb.var():.2e}")
        emit(f"apph/ternary/n{n}", 0.0,
             f"maxerr={et.max():.4f} var={et.var():.2e}")
    # fitted envelope exponents (log-log fit of max error vs n)
    emit("apph/fit", 0.0, _fit(rng))


def _fit(rng):
    ns, bmax, tmax = [], [], []
    for bits in [8, 10, 12, 14]:
        n = 2 ** bits
        eb, et = [], []
        for _ in range(200):
            x = rng.integers(0, 2**32, size=n).astype(np.float64)
            for est, errs in ((binary_tree_median, eb),
                              (ternary_tree_median, et)):
                v = est(x, rng=rng) if est is binary_tree_median else est(x, rng)
                r = np.searchsorted(np.sort(x), v)
                errs.append(abs(r / (n - 1) - 0.5) + 1e-9)
        ns.append(n)
        bmax.append(max(eb))
        tmax.append(max(et))
    gb = -np.polyfit(np.log(ns), np.log(bmax), 1)[0]
    gt = -np.polyfit(np.log(ns), np.log(tmax), 1)[0]
    return f"binary gamma={gb:.3f} ternary gamma={gt:.3f} (paper: 0.39/0.37)"


if __name__ == "__main__":
    main()
