"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

The distributed benchmarks need p>1 PEs, so this entry point runs with 8
emulated CPU devices (set before jax import; the 512-device setting stays
confined to the dry-run per the project brief).
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys                                    # noqa: E402
from pathlib import Path                      # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent))

BENCHES = ["apph_median", "table1_comm", "fig2_robustness",
           "fig1_input_sizes", "moe_dispatch"]


def main() -> None:
    import importlib
    only = sys.argv[1:] or BENCHES
    print("name,us_per_call,derived")
    for name in only:
        mod = importlib.import_module(name)
        print(f"# --- {name} ---", flush=True)
        mod.main()


if __name__ == "__main__":
    main()
