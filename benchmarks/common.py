"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
import time

import numpy as np

ROWS = []


def timeit(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6      # µs


def emit(name: str, us_per_call: float, derived=""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
