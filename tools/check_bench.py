"""Perf-regression gate (CI `calibrate` job).

Compares a freshly measured ``BENCH_calibrate.json`` (written by
``benchmarks/calibrate.py``) against the committed baseline, cell by cell
(one cell = one ``bench[p][algorithm][log2(n/p)]`` wall-clock in µs):

  * **fail**  — any common cell slower than ``--fail-ratio``  (default 1.5×);
  * **warn**  — slower than ``--warn-ratio`` (default 1.2×);
  * **report** — improvements (faster than 1/warn-ratio), cells new in the
    fresh run (no baseline yet — e.g. a widened sweep), and cells the fresh
    run dropped.  With ``--fail-on-dropped`` (on in the PR CI lanes) a
    dropped baseline cell is a gate failure, not a report line — deleting
    a bench cell must not silently pass.

Wall-clock gating across runner generations is noisy, which is exactly why
the thresholds are ratios per cell rather than absolute times, and why the
gate *fails* only on large regressions while merely warning on drift.
When a legitimate change shifts the baseline (new machine, new sweep
grid), regenerate and commit it:

    PYTHONPATH=src python benchmarks/calibrate.py --p 64 256 --nested 8 8 \
        --machine ci-ubuntu-sim --profile-dir /tmp/profiles

Run the gate::

    python tools/check_bench.py --fresh BENCH_fresh.json
    python tools/check_bench.py --baseline BENCH_calibrate.json \
        --fresh BENCH_fresh.json --fail-ratio 1.5 --warn-ratio 1.2
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def iter_cells(bench: dict):
    """Yield ((p, algorithm, e), us) for every cell of a bench mapping."""
    for p, algos in sorted(bench.items()):
        for algo, cells in sorted(algos.items()):
            for e, us in sorted(cells.items()):
                yield (p, algo, e), float(us)


def compare(baseline: dict, fresh: dict, fail_ratio: float = 1.5,
            warn_ratio: float = 1.2, fail_on_dropped: bool = False) -> dict:
    """Per-cell ratio comparison of two bench JSON dicts.

    Returns {"fail": [...], "warn": [...], "improved": [...], "new": [...],
    "dropped": [...], "ok": [...]}; each entry is (cell_key, ratio-or-None).
    A cell fails when fresh/baseline > fail_ratio.

    ``fail_on_dropped`` additionally moves every dropped baseline cell
    (present in the baseline, missing from the fresh run) into ``fail``:
    a change that silently stops producing a gated cell would otherwise
    pass the gate with the regression invisible.  Off by default so
    intentionally narrower sweeps (the nightly deep job's grid differs
    from the PR baseline) can still run report-only.
    """
    base_cells = dict(iter_cells(baseline.get("bench", {})))
    fresh_cells = dict(iter_cells(fresh.get("bench", {})))
    out = {"fail": [], "warn": [], "improved": [], "new": [], "dropped": [],
           "ok": []}
    for key, us in sorted(fresh_cells.items()):
        if key not in base_cells:
            out["new"].append((key, None))
            continue
        ratio = us / max(base_cells[key], 1e-9)
        if ratio > fail_ratio:
            out["fail"].append((key, ratio))
        elif ratio > warn_ratio:
            out["warn"].append((key, ratio))
        elif ratio < 1.0 / warn_ratio:
            out["improved"].append((key, ratio))
        else:
            out["ok"].append((key, ratio))
    for key in sorted(base_cells):
        if key not in fresh_cells:
            out["dropped"].append((key, None))
            if fail_on_dropped:
                out["fail"].append((key, None))
    return out


def _fmt(key, ratio):
    p, algo, e = key
    cell = f"p={p} {algo} n/p=2^{e}"
    return cell if ratio is None else f"{cell}: {ratio:.2f}x"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default=str(REPO / "BENCH_calibrate.json"),
                    help="committed baseline bench JSON")
    ap.add_argument("--fresh", required=True,
                    help="freshly measured bench JSON to gate")
    ap.add_argument("--fail-ratio", type=float, default=1.5)
    ap.add_argument("--warn-ratio", type=float, default=1.2)
    ap.add_argument("--fail-on-dropped", action="store_true",
                    help="treat baseline cells missing from the fresh run "
                         "as gate failures (on in the PR CI lanes; leave "
                         "off for report-only runs whose sweep grid "
                         "legitimately differs from the baseline)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    if baseline.get("machine") != fresh.get("machine"):
        print(f"note: machine mismatch (baseline "
              f"{baseline.get('machine')!r} vs fresh "
              f"{fresh.get('machine')!r}) — ratios compare across machines")
    elif baseline.get("host") != fresh.get("host"):
        print(f"note: same machine label but different hosts (baseline "
              f"{baseline.get('host')!r} vs fresh {fresh.get('host')!r}) — "
              f"a freshly seeded baseline meets its real runner here for "
              f"the first time; if ratios drift for hardware reasons, "
              f"regenerate the baseline from this run's artifact")

    res = compare(baseline, fresh, args.fail_ratio, args.warn_ratio,
                  fail_on_dropped=args.fail_on_dropped)
    n_common = sum(len(res[k]) for k in ("fail", "warn", "improved", "ok"))
    print(f"compared {n_common} cells "
          f"({len(res['new'])} new, {len(res['dropped'])} dropped)")
    for key, ratio in res["improved"]:
        print(f"IMPROVED  {_fmt(key, ratio)}")
    for key, _ in res["new"]:
        print(f"NEW       {_fmt(key, None)} (no baseline — commit a "
              f"regenerated BENCH_calibrate.json to start gating it)")
    for key, _ in res["dropped"]:
        print(f"DROPPED   {_fmt(key, None)}")
    for key, ratio in res["warn"]:
        print(f"WARN      {_fmt(key, ratio)} "
              f"(> {args.warn_ratio}x baseline)")
    for key, ratio in res["fail"]:
        if ratio is None:
            print(f"FAIL      {_fmt(key, None)} (baseline cell dropped "
                  f"from the fresh run; --fail-on-dropped)")
        else:
            print(f"FAIL      {_fmt(key, ratio)} "
                  f"(> {args.fail_ratio}x baseline)")
    if res["fail"]:
        print(f"perf gate FAILED: {len(res['fail'])} cell(s) regressed "
              f"(> {args.fail_ratio}x) or dropped — if intentional, "
              f"regenerate the committed baseline (see module docstring)")
        return 1
    print(f"perf gate OK ({len(res['warn'])} warning(s), "
          f"{len(res['improved'])} improvement(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
