"""Docs-freshness gate (CI `docs` job).

Two checks, both offline and deterministic:

1. **EXPERIMENTS.md freshness** — regenerates the file via
   ``benchmarks/calibrate.py --experiments-only`` into a temp path and
   diffs it against the committed copy.  The regime tables and the
   subgroup-sort grid are pure functions of the cost model and the
   counted collective traces, so any drift means someone edited the file
   by hand or changed the generators without regenerating.

2. **Markdown link integrity** — every relative link target in the
   tracked docs (README.md, ROADMAP.md, EXPERIMENTS.md, docs/*.md) must
   exist on disk.  External (http/https/mailto) links and pure anchors
   are skipped.

Run from the repo root::

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import difflib
import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "ROADMAP.md", "EXPERIMENTS.md"]

# [text](target) — excludes images' leading ! only in that we don't care;
# image targets must exist too.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


_PROFILE = re.compile(r"Machine profile: \*\*([^*]+)\*\*")


def _committed_profile_args(text: str) -> list[str]:
    """Regenerate with the same profile the committed file was built from.

    The generator stamps ``Machine profile: **<name>**`` into the header;
    when a matching ``profiles/<name>.json`` exists the file came from
    ``--profile`` and the gate must pass it too, else the default prior
    profile applies (its name matches no file).
    """
    m = _PROFILE.search(text)
    if m:
        candidate = REPO / "profiles" / f"{m.group(1).strip()}.json"
        if candidate.exists():
            return ["--profile", str(candidate)]
    return []


def check_experiments() -> list[str]:
    committed = REPO / "EXPERIMENTS.md"
    if not committed.exists():
        return ["EXPERIMENTS.md is missing"]
    with tempfile.NamedTemporaryFile(suffix=".md", delete=False) as f:
        tmp = f.name
    proc = subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "calibrate.py"),
         "--experiments-only", "--experiments", tmp,
         *_committed_profile_args(committed.read_text())],
        cwd=REPO, capture_output=True, text=True)
    if proc.returncode != 0:
        return [f"calibrate.py --experiments-only failed:\n{proc.stderr}"]
    fresh = Path(tmp).read_text()
    stale = committed.read_text()
    if fresh == stale:
        return []
    diff = "".join(difflib.unified_diff(
        stale.splitlines(keepends=True), fresh.splitlines(keepends=True),
        fromfile="EXPERIMENTS.md (committed)",
        tofile="EXPERIMENTS.md (regenerated)", n=2))
    return ["EXPERIMENTS.md drifted from `calibrate.py --experiments-only` "
            "output; regenerate it:\n" + diff]


def check_links() -> list[str]:
    errors = []
    docs = [REPO / f for f in DOC_FILES]
    docs += sorted((REPO / "docs").glob("*.md")) if (REPO / "docs").exists() \
        else []
    for doc in docs:
        if not doc.exists():
            errors.append(f"{doc.relative_to(REPO)}: file missing")
            continue
        for m in _LINK.finditer(doc.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{doc.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def main() -> int:
    errors = check_experiments() + check_links()
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print("docs OK: EXPERIMENTS.md fresh, all relative links resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
