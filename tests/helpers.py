"""Shared helpers: the sort-correctness contract every algorithm must meet."""
import numpy as np

from repro.core.api import SortConfig, psort


def check_sort(x, p, algorithm, *, check_balance=False, expect_overflow=False,
               **kw):
    """Assert output == np.sort(input), exact multiset, zero overflow."""
    cfg = SortConfig.from_kwargs(p=p, algorithm=algorithm, **kw)
    out, info = psort(np.asarray(x), config=cfg, return_info=True)
    out = np.asarray(out)
    ref = np.sort(np.asarray(x))
    if expect_overflow:
        assert info["overflow"] > 0, \
            f"{algorithm} expected to overflow on this instance"
        return info
    assert info["overflow"] == 0, \
        f"{algorithm} overflowed by {info['overflow']} on n={len(x)} p={p}"
    assert out.shape == ref.shape, (out.shape, ref.shape)
    assert (out == ref).all(), \
        f"{algorithm} mis-sorted (first diff at " \
        f"{np.argmax(out != ref) if len(out) else 0})"
    if len(x):
        perm = info["perm"]
        assert len(np.unique(perm)) == len(x), \
            f"{algorithm} lost/duplicated payload elements"
    if check_balance and len(x) >= p:
        assert info["balance"] <= 3.0, \
            f"{algorithm} output imbalance {info['balance']:.2f}"
    return info
