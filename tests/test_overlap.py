"""Exchange/merge overlap: the streamed pipeline vs the barrier path.

The acceptance bar of the overlap PR: ``SortConfig(overlap=True)`` must be
**bitwise equal** to the barrier path — keys, perm, counts, overflow — for
every algorithm on both backends.  Algorithms without a slotted exchange
(``_OVERLAP_ALGOS`` excludes them) run the barrier path unchanged; the
slotted ones (rams, ssort and their NTB variants) route every post-shuffle
exchange through ``Collectives.alltoall_stream`` and fold each arriving
source block into an incremental merge, so equality here proves the fold
is insensitive to the delivery interleaving the stream contract leaves
implementation-defined.

The trace section checks the per-chunk cost attribution: under
``CountingCollectives`` every streamed exchange is recorded as ``gsize``
``ovl:<tag>`` events whose bytes sum to exactly the barrier exchange it
replaces — the calibrator's wire aggregates must not change because the
schedule did.
"""
import numpy as np
import pytest

from repro.core.api import SortConfig, _OVERLAP_ALGOS, psort, \
    trace_collectives
from repro.core import ExternalPolicy
from repro.data.distributions import INSTANCES, generate_instance

ALL_ALGOS = ["rquick", "rfis", "rams", "bitonic", "ssort", "gatherm",
             "allgatherm"]
ALL_INSTANCES = sorted(INSTANCES)
# classical sample sort overflows on heavy duplicates by design — same
# exclusions as the differential matrix; overlap must not change that
SSORT_SKIP = {"Zero", "DeterDupl", "RandDupl", "Mirrored"}

P = 8


def _assert_overlap_bitwise(x, algorithm, backend, p=P):
    cfg = SortConfig(p=p, algorithm=algorithm, backend=backend)
    out_b, info_b = psort(x, config=cfg, return_info=True)
    out_s, info_s = psort(x, config=cfg.replace(overlap=True),
                          return_info=True)
    assert (np.asarray(out_s) == np.asarray(out_b)).all(), \
        (algorithm, backend)
    assert (info_s["perm"] == info_b["perm"]).all(), (algorithm, backend)
    assert (info_s["counts"] == info_b["counts"]).all(), (algorithm, backend)
    assert info_s["overflow"] == info_b["overflow"]


# ---------------------------------------------------------------------------
# Acceptance: bitwise equality, all seven algorithms, both backends.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["sim", "shard_map"])
@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_overlap_bitwise_vs_barrier(algorithm, backend):
    x = generate_instance("Staggered", P, 53 * P, seed=7).astype(np.int32)
    _assert_overlap_bitwise(x, algorithm, backend)


@pytest.mark.slow
@pytest.mark.parametrize("instance", ALL_INSTANCES)
@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_overlap_bitwise_full_matrix(algorithm, instance):
    """Nightly: the full 7-algorithm × 11-distribution matrix on sim."""
    if algorithm == "ssort" and instance in SSORT_SKIP:
        pytest.skip("ssort overflows these by design; covered below")
    x = generate_instance(instance, P, 37 * P, seed=3).astype(np.int32)
    _assert_overlap_bitwise(x, algorithm, "sim")


def test_overlap_preserves_ssort_overflow():
    """Overlap must not mask the intended ssort duplicate overflow."""
    x = generate_instance("Zero", P, 64 * P).astype(np.int32)
    cfg = SortConfig(p=P, algorithm="ssort", backend="sim")
    _, ib = psort(x, config=cfg, return_info=True)
    _, io = psort(x, config=cfg.replace(overlap=True), return_info=True)
    assert ib["overflow"] > 0 and io["overflow"] == ib["overflow"]


@pytest.mark.parametrize("n", [0, 1, 5, P - 1])
@pytest.mark.parametrize("algorithm", ["rams", "ssort"])
def test_overlap_degenerate_chunks(algorithm, n):
    """n < p: most streamed chunks carry zero live elements — the staged
    fold must still place every (possibly empty) source block correctly."""
    x = np.arange(n, dtype=np.int32)[::-1].copy()
    _assert_overlap_bitwise(x, algorithm, "sim")


def test_overlap_nested_mesh():
    """Streamed exchanges inside a hierarchical (2, 4) mesh group."""
    p = 8
    x = generate_instance("DeterDupl", p, 32 * p, seed=5).astype(np.int32)
    cfg = SortConfig(mesh_shape=(2, 4), algorithm="rams", backend="sim")
    out_b = np.asarray(psort(x, config=cfg))
    out_s = np.asarray(psort(x, config=cfg.replace(overlap=True)))
    assert (out_s == out_b).all()
    assert (out_s == np.sort(x)).all()


def test_overlap_external_pass():
    """The out-of-core lane's per-run exchange passes stream too."""
    x = generate_instance("Staggered", P, 37 * P, seed=9).astype(np.int32)
    cfg = SortConfig(p=P, backend="sim", external=ExternalPolicy(budget=8))
    out_b, ib = psort(x, config=cfg, return_info=True)
    out_s, io = psort(x, config=cfg.replace(overlap=True), return_info=True)
    assert ib["algorithm"] == io["algorithm"] == "external"
    assert (np.asarray(out_s) == np.asarray(out_b)).all()
    assert (np.asarray(out_s) == np.sort(x)).all()


# ---------------------------------------------------------------------------
# Trace attribution: per-chunk ovl:* events, conserved wire bytes.
# ---------------------------------------------------------------------------


def test_overlap_trace_chunk_attribution():
    n, p = 64 * P, P
    cfg = SortConfig(p=p, algorithm="rams")
    tb = trace_collectives(n, cfg)
    ts = trace_collectives(n, cfg.replace(overlap=True))
    # schedule change must not change the calibrator's wire aggregate
    assert ts.wire_bytes() == tb.wire_bytes()
    ovl_tags = {t for t in ts.tags() if t.startswith("ovl:")}
    assert ovl_tags, "no streamed exchange recorded"
    for tag in ovl_tags:
        base = tag[len("ovl:"):]
        ovl = [e for e in ts.events
               if e.tag == tag and e.primitive == "all_to_all"]
        # one event per source block: the chunk granularity is visible
        assert len(ovl) % p == 0 and len(ovl) > 0
        barrier_bytes = sum(e.bytes for e in tb.events
                            if e.tag == base and e.primitive == "all_to_all")
        plain_bytes = sum(e.bytes for e in ts.events
                          if e.tag == base and e.primitive == "all_to_all")
        # the ovl:* chunks account byte-for-byte for the barrier a2a they
        # replace (any a2a left under the plain tag stayed barrier)
        assert sum(e.bytes for e in ovl) + plain_bytes == barrier_bytes, tag


def test_overlap_trace_ssort():
    cfg = SortConfig(p=P, algorithm="ssort")
    tb = trace_collectives(48 * P, cfg)
    ts = trace_collectives(48 * P, cfg.replace(overlap=True))
    assert ts.wire_bytes() == tb.wire_bytes()
    assert any(t.startswith("ovl:") for t in ts.tags())


def test_overlap_noop_for_unslotted_algorithms():
    """rquick has no slotted exchange: overlap=True leaves its trace
    untouched (barrier path, no ovl events)."""
    cfg = SortConfig(p=P, algorithm="rquick")
    tb = trace_collectives(64 * P, cfg)
    ts = trace_collectives(64 * P, cfg.replace(overlap=True))
    assert not any(t.startswith("ovl:") for t in ts.tags())
    assert ts.summary() == tb.summary()


def test_overlap_algos_registry():
    """The streamable set is exactly the slotted-exchange algorithms."""
    assert set(_OVERLAP_ALGOS) == {"rams", "ntb-ams", "ssort", "ns-ssort"}
