"""Differential tests for the fused partition-into-buckets primitive.

Three implementations must agree bitwise everywhere:

  * the pre-existing O(n·nb) one-hot formulation (kept here as a numpy
    oracle — it's what ``rams._rams_level`` shipped before the rewrite);
  * ``partition_ref`` — the jnp reference the sim backend runs;
  * the Pallas kernel (interpret mode on CPU) behind ``partition_buckets``.

Plus the structural guarantee the rewrite exists for: no O(n·nb)
intermediate is materialized anywhere in a traced RAMS level.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401  — flips jax_enable_x64 on
from repro.core.types import (LocalKernelPolicy, local_kernels,
                              set_local_kernels, set_pallas_local_sort)
from repro.data.distributions import INSTANCES, generate_instance
from repro.kernels.partition import partition_buckets, partition_ref

AXIS = "pe"


@pytest.fixture
def clean_policy(monkeypatch):
    """No env vars, no programmatic overrides — restores both on exit."""
    monkeypatch.delenv("REPRO_LOCAL_KERNELS", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_LOCAL_SORT", raising=False)
    prev_pol = set_local_kernels(None)
    prev_sort = set_pallas_local_sort(None)
    yield
    set_local_kernels(prev_pol)
    set_pallas_local_sort(prev_sort)


# ---------------------------------------------------------------------------
# the pre-existing path, as a numpy oracle
# ---------------------------------------------------------------------------

def onehot_oracle(keys, ties, s_keys, s_ties, *, n_buckets, count,
                  inclusive=True):
    """O(n·nb) one-hot classify/rank/histogram — the formulation the fused
    primitive replaced (rams._rams_level pre-rewrite, kernels/kway ref)."""
    elem = (keys.astype(np.uint64) << np.uint64(32)) | ties.astype(np.uint64)
    spl = (s_keys.astype(np.uint64) << np.uint64(32)) | s_ties.astype(np.uint64)
    cmp = spl[None, :] <= elem[:, None] if inclusive \
        else spl[None, :] < elem[:, None]
    bucket = cmp.sum(axis=1).astype(np.int32)
    C = keys.shape[0]
    bucket = np.where(np.arange(C) < count, bucket, np.int32(n_buckets))
    onehot = bucket[:, None] == np.arange(n_buckets + 1)[None, :]
    hist = onehot[:, :n_buckets].sum(axis=0).astype(np.int32)
    pos = np.where(onehot, np.cumsum(onehot, axis=0) - 1, 0) \
        .sum(axis=1).astype(np.int32)
    return bucket, pos, hist


def _mix(x):
    x = x.astype(np.uint32)
    x ^= x >> 16
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> 13
    return x


def _case(name, C, n_buckets, count, seed=0, tie=True):
    """A locally-sorted (keys, ties) shard + quantile splitters, all u32."""
    gen = INSTANCES[name]
    raw = gen(3, 8, count, seed=seed).astype(np.uint32)
    keys = np.full(C, 0xFFFFFFFF, np.uint32)
    keys[:count] = np.sort(raw)
    ties = _mix(np.arange(C, dtype=np.uint32)) if tie \
        else np.zeros(C, np.uint32)
    ties[count:] = 0xFFFFFFFF
    rng = np.random.default_rng(seed + 1)
    samp = rng.choice(raw, size=max(count, 1), replace=True) if count else \
        np.zeros(1, np.uint32)
    s_keys = np.sort(samp)[
        np.minimum(np.arange(1, n_buckets) * len(samp) // n_buckets,
                   len(samp) - 1)].astype(np.uint32)
    s_ties = _mix(np.arange(n_buckets - 1, dtype=np.uint32)) if tie \
        else np.zeros(n_buckets - 1, np.uint32)
    # splitter composites must be nondecreasing under (key, tie) lex order
    comp = (s_keys.astype(np.uint64) << np.uint64(32)) | s_ties
    order = np.argsort(comp, kind="stable")
    return keys, ties, s_keys[order], s_ties[order]


REF_CASES = [
    ("Uniform", 1024, 64, 1024), ("Uniform", 1000, 8, 777),
    ("Zero", 1024, 64, 1024), ("Zero", 257, 16, 200),
    ("DeterDupl", 512, 32, 512), ("RandDupl", 384, 64, 300),
    ("Staggered", 2048, 128, 2048), ("Mirrored", 192, 2, 100),
    ("Uniform", 256, 16, 0), ("Reverse", 130, 4, 130),
]


@pytest.mark.parametrize("name,C,nb,count", REF_CASES)
@pytest.mark.parametrize("inclusive", [True, False])
def test_partition_ref_matches_onehot_oracle(name, C, nb, count, inclusive):
    keys, ties, sk, st = _case(name, C, nb, count)
    ob, op, oh = onehot_oracle(keys, ties, sk, st, n_buckets=nb, count=count,
                               inclusive=inclusive)
    rb, rp, rh = jax.jit(
        lambda *a: partition_ref(*a, n_buckets=nb, count=count,
                                 inclusive=inclusive)
    )(keys, ties, sk, st)
    np.testing.assert_array_equal(np.asarray(rb), ob)
    np.testing.assert_array_equal(np.asarray(rh), oh)
    assert int(rh.sum()) == count
    # ranks: the oracle gives invalid elements rank 0 (they are in no real
    # bucket); the fused primitive ranks them inside the trash bucket —
    # compare valid entries, and check trash ranks are the stable 0..n-1
    np.testing.assert_array_equal(np.asarray(rp)[:count], op[:count])
    np.testing.assert_array_equal(np.asarray(rp)[count:],
                                  np.arange(C - count, dtype=np.int32))


def test_partition_ref_no_tie_plane():
    keys, ties, sk, st = _case("DeterDupl", 512, 32, 512, tie=False)
    ob, op, oh = onehot_oracle(keys, ties, sk, st, n_buckets=32, count=512)
    rb, rp, rh = partition_ref(keys, ties, sk, st, n_buckets=32, count=512)
    np.testing.assert_array_equal(np.asarray(rb), ob)
    np.testing.assert_array_equal(np.asarray(rp), op)
    np.testing.assert_array_equal(np.asarray(rh), oh)


def test_partition_ref_want_pos_false():
    keys, ties, sk, st = _case("Uniform", 512, 16, 400)
    b1, p1, h1 = partition_ref(keys, ties, sk, st, n_buckets=16, count=400)
    b2, p2, h2 = partition_ref(keys, ties, sk, st, n_buckets=16, count=400,
                               want_pos=False)
    assert p2 is None
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


# ---------------------------------------------------------------------------
# the Pallas kernel (interpret mode) vs the jnp reference
# ---------------------------------------------------------------------------

# nb sweeps the SSSS fan-outs (2 = rquick's split, 128 = deep RAMS level);
# C covers tile-multiple, non-multiple-of-128 and non-pow2 capacities.
KERNEL_CASES = [
    ("Uniform", 1024, 64, 1024), ("Uniform", 1000, 8, 777),
    ("Zero", 1024, 64, 1024), ("Zero", 257, 16, 200),
    ("DeterDupl", 512, 32, 512), ("RandDupl", 384, 128, 300),
    ("Staggered", 4096, 128, 4096), ("Mirrored", 192, 2, 100),
    ("Uniform", 256, 16, 0), ("Reverse", 130, 2, 130),
    ("g-Group", 8256, 64, 8000),
]


@pytest.mark.parametrize("name,C,nb,count", KERNEL_CASES)
@pytest.mark.parametrize("inclusive", [True, False])
def test_partition_kernel_matches_ref(name, C, nb, count, inclusive):
    keys, ties, sk, st = _case(name, C, nb, count)
    args = tuple(map(jnp.asarray, (keys, ties, sk, st)))
    kb, kp, kh = partition_buckets(*args, n_buckets=nb, count=count,
                                   inclusive=inclusive, use_kernel=True)
    rb, rp, rh = partition_buckets(*args, n_buckets=nb, count=count,
                                   inclusive=inclusive, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(kh), np.asarray(rh))
    assert int(np.asarray(kh).sum()) == count


def test_partition_kernel_vmap_batch():
    """The kernel must survive jax batching (the sim backend vmaps every
    per-PE body): 4 lanes with heterogeneous counts vs per-lane ref."""
    B, C, nb = 4, 512, 16
    counts = np.array([512, 300, 1, 0], np.int32)
    lanes = [_case("RandDupl", C, nb, int(c), seed=i)
             for i, c in enumerate(counts)]
    keys = jnp.asarray(np.stack([l[0] for l in lanes]))
    ties = jnp.asarray(np.stack([l[1] for l in lanes]))
    sk = jnp.asarray(lanes[0][2])
    st = jnp.asarray(lanes[0][3])

    def one(k, t, c):
        return partition_buckets(k, t, sk, st, n_buckets=nb, count=c,
                                 use_kernel=True)

    bb, bp, bh = jax.vmap(one)(keys, ties, jnp.asarray(counts))
    for i in range(B):
        rb, rp, rh = partition_buckets(
            keys[i], ties[i], sk, st, n_buckets=nb, count=int(counts[i]),
            use_kernel=False)
        np.testing.assert_array_equal(np.asarray(bb)[i], np.asarray(rb))
        np.testing.assert_array_equal(np.asarray(bp)[i], np.asarray(rp))
        np.testing.assert_array_equal(np.asarray(bh)[i], np.asarray(rh))


def test_partition_kernel_falls_back_below_lane_width():
    """C < 128 can't tile a VPU row — the wrapper must silently take the
    jnp reference and still be exact."""
    keys, ties, sk, st = _case("Uniform", 64, 8, 50)
    kb, kp, kh = partition_buckets(keys, ties, sk, st, n_buckets=8, count=50,
                                   use_kernel=True)
    rb, rp, rh = partition_buckets(keys, ties, sk, st, n_buckets=8, count=50,
                                   use_kernel=False)
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(kh), np.asarray(rh))


# ---------------------------------------------------------------------------
# kernel policy: env parsing, overrides, legacy interplay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec,expect", [
    ("all", (True, True)), ("1", (True, True)), ("on", (True, True)),
    ("", (False, False)), ("0", (False, False)), ("none", (False, False)),
    ("off", (False, False)), ("sort", (True, False)),
    ("partition", (False, True)), ("sort,partition", (True, True)),
    ("partition, sort", (True, True)),
])
def test_local_kernels_env_parsing(clean_policy, monkeypatch, spec, expect):
    monkeypatch.setenv("REPRO_LOCAL_KERNELS", spec)
    pol = local_kernels()
    assert (pol.sort, pol.partition) == expect


def test_local_kernels_env_auto_is_backend_default(clean_policy, monkeypatch):
    monkeypatch.setenv("REPRO_LOCAL_KERNELS", "auto")
    on = jax.default_backend() == "tpu"
    assert local_kernels() == LocalKernelPolicy(sort=on, partition=on)


def test_local_kernels_env_rejects_unknown(clean_policy, monkeypatch):
    monkeypatch.setenv("REPRO_LOCAL_KERNELS", "sort,warp")
    with pytest.raises(ValueError, match="warp"):
        local_kernels()


def test_set_local_kernels_beats_env(clean_policy, monkeypatch):
    monkeypatch.setenv("REPRO_LOCAL_KERNELS", "none")
    prev = set_local_kernels(LocalKernelPolicy(sort=False, partition=True))
    try:
        assert local_kernels() == LocalKernelPolicy(sort=False,
                                                    partition=True)
    finally:
        set_local_kernels(prev)


def test_legacy_sort_flag_layers_onto_policy(clean_policy, monkeypatch):
    # env form: REPRO_PALLAS_LOCAL_SORT only touches the sort component
    monkeypatch.setenv("REPRO_LOCAL_KERNELS", "partition")
    monkeypatch.setenv("REPRO_PALLAS_LOCAL_SORT", "1")
    assert local_kernels() == LocalKernelPolicy(sort=True, partition=True)
    monkeypatch.setenv("REPRO_PALLAS_LOCAL_SORT", "0")
    assert local_kernels() == LocalKernelPolicy(sort=False, partition=True)
    # programmatic form
    monkeypatch.delenv("REPRO_PALLAS_LOCAL_SORT")
    prev = set_pallas_local_sort(True)
    try:
        assert local_kernels().sort is True
    finally:
        set_pallas_local_sort(prev)


# ---------------------------------------------------------------------------
# end to end: psort with kernels on vs off must agree bitwise everywhere
# ---------------------------------------------------------------------------

ALL_ALGOS = ["rquick", "rfis", "rams", "bitonic", "ssort", "gatherm",
             "allgatherm"]
CORE_INSTANCES = ["Uniform", "Zero", "g-Group", "Staggered"]
# instances where classical sample sort legitimately overflows its static
# slots at small p (same subset test_differential.py carves out): there the
# contract is off == on, not overflow == 0.
SSORT_OVERFLOWS = ("Zero", "DeterDupl", "RandDupl", "Mirrored")


def _e2e_cells():
    for algorithm in ALL_ALGOS:
        for instance in sorted(INSTANCES):
            marks = [] if instance in CORE_INSTANCES else [pytest.mark.slow]
            yield pytest.param(algorithm, instance, marks=marks,
                               id=f"{algorithm}-{instance}")


@pytest.mark.parametrize("algorithm,instance", list(_e2e_cells()))
def test_psort_kernel_policy_bitwise(clean_policy, algorithm, instance):
    from repro.core.api import SortConfig, psort
    p = 8
    x = generate_instance(instance, p, 32 * p, seed=3).astype(np.int32)
    set_local_kernels(LocalKernelPolicy())
    cfg = SortConfig(p=p, algorithm=algorithm, backend="sim")
    off, i0 = psort(x, config=cfg, return_info=True)
    set_local_kernels(LocalKernelPolicy(sort=True, partition=True))
    on, i1 = psort(x, config=cfg, return_info=True)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    assert i0["overflow"] == i1["overflow"]
    if algorithm != "ssort" or instance not in SSORT_OVERFLOWS:
        assert i1["overflow"] == 0
        np.testing.assert_array_equal(np.asarray(on), np.sort(x))


def test_local_kernels_env_busts_psort_jit_cache(clean_policy, monkeypatch):
    """Flipping REPRO_LOCAL_KERNELS between same-signature psort calls must
    retrace (the policy keys the jit cache), not reuse the kernel-less
    executable — and the retraced result must stay bitwise identical."""
    import repro.core.rams as rams_mod
    from repro.core.api import SortConfig, psort
    rng = np.random.default_rng(7)
    x = rng.integers(0, 1 << 20, size=2048).astype(np.int32)

    cfg = SortConfig(p=4, algorithm="rams", backend="sim")
    out_plain = psort(x, config=cfg)

    called = []
    real = rams_mod.partition_buckets
    monkeypatch.setattr(
        rams_mod, "partition_buckets",
        lambda *a, **k: (called.append(1), real(*a, **k))[1])
    monkeypatch.setenv("REPRO_LOCAL_KERNELS", "partition")
    out_kern = psort(x, config=cfg)
    assert called, "policy flip did not retrace psort"
    np.testing.assert_array_equal(np.asarray(out_plain), np.asarray(out_kern))


# ---------------------------------------------------------------------------
# structural: no O(n·nb) intermediate survives in a traced RAMS level
# ---------------------------------------------------------------------------

def _walk_eqns(jaxpr, fn):
    for eqn in jaxpr.eqns:
        fn(eqn)
        for v in eqn.params.values():
            _walk_param(v, fn)


def _walk_param(v, fn):
    if isinstance(v, (tuple, list)):
        for x in v:
            _walk_param(x, fn)
    elif hasattr(v, "eqns"):               # Jaxpr
        _walk_eqns(v, fn)
    elif hasattr(v, "jaxpr"):              # ClosedJaxpr
        _walk_eqns(v.jaxpr, fn)


def test_rams_trace_free_of_onb_intermediates():
    """Trace a full sim-backend RAMS sort at nb=64 and assert the largest
    intermediate stays O(cap) per PE — the old one-hot path materialized
    (2·cap, nb) = 8·16× over this test's threshold."""
    from repro.core import comm
    from repro.core.api import _sort_body

    P, PER, CAP = 16, 512, 1024            # levels=1 at p=16 → nb = 4·16 = 64
    body = _sort_body(AXIS, P, "rams", CAP, CAP, (("levels", 1),))
    runner = comm.sim_map(body, AXIS, P)
    keys2d = jax.ShapeDtypeStruct((P, PER), jnp.uint32)
    counts = jax.ShapeDtypeStruct((P,), jnp.int32)
    jaxpr = jax.make_jaxpr(runner)(keys2d, counts)

    biggest = {"numel": 0, "eqn": None}

    def look(eqn):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape:
                numel = int(np.prod(shape))
                if numel > biggest["numel"]:
                    biggest["numel"] = numel
                    biggest["eqn"] = str(eqn)[:200]

    _walk_eqns(jaxpr.jaxpr, look)
    # legit peak: the p·slot_cap shuffle buffer ≈ 2.9·cap per PE (×P for the
    # vmapped sim axis). The old one-hot rank was 2·cap·nb = 128·cap per PE.
    limit = P * CAP * 16
    assert biggest["numel"] <= limit, (
        f"O(n·nb)-sized intermediate back in the rams trace: "
        f"{biggest['numel']} > {limit}\n{biggest['eqn']}")
