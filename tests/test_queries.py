"""Selection fast paths vs. the full-sort oracle.

The whole point of ``core/queries.py`` is that its answers are *bitwise*
those of sorting: every differential here indexes ``np.sort`` (and, in
the property test, the repo's own ``psort``) and demands equality — on
all 11 paper input distributions, on both execution backends.
"""
import numpy as np
import pytest

from repro.core import comm, psort, queries, selection
from repro.core.api import SortConfig
from repro.core.queries import (QUERY_KINDS, n_rounds, percentile,
                                range_query, rank_of_key, select_rank,
                                shard_data, top_k, trace_query)
from repro.data.distributions import INSTANCES, generate_instance

P = 8
ALL_INSTANCES = sorted(INSTANCES)
BACKENDS = ("sim", "shard_map")


def _oracle_queries(x, data, backend):
    """Run every query kind against one instance and check bitwise."""
    srt = np.sort(x)
    n = len(x)
    # order statistics at the edges, middle, and around duplicates
    ranks = np.unique(np.clip(np.array([1, 2, n // 3, n // 2, n - 1, n]),
                              1, n))
    vals, glt, gle = select_rank(data, ranks, backend=backend)
    assert (vals == srt[ranks - 1]).all(), (vals, srt[ranks - 1])
    assert (glt < ranks).all() and (ranks <= gle).all()
    qs = np.array([0.0, 10.0, 50.0, 90.0, 99.0, 100.0])
    pv = percentile(data, qs, backend=backend)
    idx = np.floor(qs / 100.0 * (n - 1)).astype(np.int64)
    assert (pv == srt[idx]).all(), (pv, srt[idx])
    for k in (1, 3, min(40, n)):
        tk = top_k(data, k, backend=backend)
        assert (tk == srt[n - k:]).all(), (k, tk, srt[n - k:])
    keys = np.concatenate([x[:3], srt[:1], srt[-1:],
                           srt[-1:] - 1 if n else srt[-1:]])
    lt, le = rank_of_key(data, keys, backend=backend)
    assert (lt == np.searchsorted(srt, keys, "left")).all()
    assert (le == np.searchsorted(srt, keys, "right")).all()
    lo = np.minimum(x[1], x[5])
    hi = np.maximum(x[1], x[5])
    cnt = range_query(data, np.array([lo, srt[0]]), np.array([hi, srt[-1]]),
                      backend=backend)
    want = [np.searchsorted(srt, hi, "left") -
            np.searchsorted(srt, lo, "left"),
            np.searchsorted(srt, srt[-1], "left")]
    assert (cnt == np.asarray(want)).all(), (cnt, want)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("instance", ALL_INSTANCES)
def test_differential_all_instances(instance, backend):
    """top_k / percentile / rank_of_key / range_query vs. the NumPy
    oracle on every paper distribution (64-bit keys: sketch+grid only)."""
    x = generate_instance(instance, P, 64 * P).astype(np.int64)
    data = shard_data(x, P)
    _oracle_queries(x, data, backend)


@pytest.mark.parametrize("instance", ALL_INSTANCES)
def test_differential_u32_window_path(instance):
    """32-bit keys additionally exercise the §III-B butterfly-window
    candidate seeding (lifted u64 space needs headroom above the keys)."""
    x = (generate_instance(instance, P, 64 * P) % (1 << 31)).astype(np.int32)
    data = shard_data(x, P)
    assert data.bits == 32
    _oracle_queries(x, data, "sim")


@pytest.mark.parametrize("instance", ALL_INSTANCES)
def test_selection_agrees_with_fullsort_psort(instance):
    """The property the service relies on: the selection path and the
    full-sort path answer identically, bit for bit."""
    x = generate_instance(instance, P, 32 * P).astype(np.int64)
    data = shard_data(x, P)
    full = np.asarray(psort(x, config=SortConfig(p=P, backend="sim")))
    n = len(x)
    ranks = np.array([1, n // 4, n // 2, n])
    vals, _, _ = select_rank(data, ranks)
    assert (vals == full[ranks - 1]).all()
    for k in (2, 17):
        assert (top_k(data, k) == full[n - k:]).all()
    keys = x[:4]
    lt, le = rank_of_key(data, keys)
    assert (lt == np.searchsorted(full, keys, "left")).all()
    assert (le == np.searchsorted(full, keys, "right")).all()


def test_float_and_negative_keys():
    r = np.random.default_rng(3)
    for x in (r.normal(size=400).astype(np.float32),
              r.integers(-2**31, 2**31, size=400).astype(np.int32),
              r.normal(size=400).astype(np.float64)):
        data = shard_data(x, P)
        srt = np.sort(x)
        assert (top_k(data, 10) == srt[-10:]).all()
        assert percentile(data, 50.0) == srt[len(x) // 2 - 1 +
                                             (len(x) % 2)]
        lt, le = rank_of_key(data, x[7])
        assert lt == np.searchsorted(srt, x[7], "left")
        assert le == np.searchsorted(srt, x[7], "right")


def test_backends_bitwise_identical():
    x = generate_instance("Staggered", P, 64 * P).astype(np.int64)
    data = shard_data(x, P)
    ranks = np.array([1, 100, 512])
    a = select_rank(data, ranks, backend="sim")
    b = select_rank(data, ranks, backend="shard_map")
    for u, v in zip(a, b):
        assert (u == v).all()
    assert all((u == v).all() for u, v in
               zip(top_k(data, np.array([5, 9]), backend="sim"),
                   top_k(data, np.array([5, 9]), backend="shard_map")))


def test_scalar_and_batch_api():
    x = np.arange(100, dtype=np.int64)
    data = shard_data(x, 4)
    assert top_k(data, 3).tolist() == [97, 98, 99]
    assert percentile(data, 0.0) == 0
    assert rank_of_key(data, 50) == (50, 51)
    assert range_query(data, 10, 20) == 10
    assert range_query(data, 20, 10) == 0          # empty interval
    vals, glt, gle = select_rank(data, np.array([1, 100]))
    assert vals.tolist() == [0, 99]
    assert glt.tolist() == [0, 99] and gle.tolist() == [1, 100]


def test_validation_errors():
    data = shard_data(np.arange(16, dtype=np.int32), 4)
    with pytest.raises(ValueError, match="power of two"):
        shard_data(np.arange(9), 3)
    with pytest.raises(ValueError, match="1-D"):
        shard_data(np.zeros((2, 2)), 2)
    with pytest.raises(ValueError, match="ranks"):
        select_rank(data, 0)
    with pytest.raises(ValueError, match="ranks"):
        select_rank(data, 17)
    with pytest.raises(ValueError, match="k must"):
        top_k(data, 0)
    with pytest.raises(ValueError, match="percentile"):
        percentile(data, 101.0)
    with pytest.raises(ValueError, match="backend"):
        top_k(data, 1, backend="mpi")


def test_trace_query_counts():
    """The counted collective schedule is deterministic: counting queries
    cost one fused psum; selection queries cost the butterfly window plus
    (gather + psum) per refinement round plus the verify psum."""
    t = trace_query("rank_of_key", 1 << 12, P, batch=4)
    assert t.summary()["counts"] == {"psum": 1}
    assert t.tags() == ["query:counts"]
    r32, r64 = n_rounds(32), n_rounds(64)
    t = trace_query("percentile", 1 << 12, P, batch=4, dtype=np.uint32)
    c = t.summary()["counts"]
    assert c["all_gather"] == r32 and c["psum"] == r32 + 1
    assert c["ppermute"] == 3                      # log2(8) window steps
    t = trace_query("top_k", 1 << 12, P, batch=4, dtype=np.uint64, k=8)
    c = t.summary()["counts"]
    assert c["all_gather"] == r64 and c["psum"] == r64 + 1
    assert "ppermute" not in c                     # no u64 window
    assert "all_to_all" not in c                   # never moves the data
    tags = set(trace_query("percentile", 1 << 12, P).tags())
    assert {"query:round0", "query:verify", "query:window"} <= tags


def test_cost_select_and_query_selection():
    """The cost model's serving regime: sort-free selection wins at scale
    (its terms are polylog in n), the full sort wins on tiny instances
    (fixed round launches dominate), and the committed BENCH cells' p
    values sit on the selection side for top-k/percentile."""
    for p in (64, 256):
        n = (1 << 18) * p
        assert selection.select_algorithm(n, p, query="top_k",
                                          k=16) == "selection"
        assert selection.select_algorithm(n, p,
                                          query="percentile") == "selection"
        assert selection.select_algorithm(n, p,
                                          query="rank_of_key") == "selection"
    assert selection.select_algorithm(64, 8, query="top_k", k=4) \
        in ("rfis", "rquick", "gatherm")
    # sort / None keep the four-regime behavior
    assert selection.select_algorithm(2**20 * 64, 64, query="sort") == \
        selection.select_algorithm(2**20 * 64, 64)
    with pytest.raises(ValueError, match="query kind"):
        selection.select_algorithm(1 << 20, 64, query="median_of_medians")
    # cost is monotone in batch and rounds (u64 costs more than u32)
    m = selection.DEFAULT_MODEL
    assert selection.cost_select(1 << 20, 64, "percentile", batch=8,
                                 model=m) > \
        selection.cost_select(1 << 20, 64, "percentile", batch=1, model=m)
    assert selection.cost_select(1 << 20, 64, "percentile", bits=64,
                                 model=m) > \
        selection.cost_select(1 << 20, 64, "percentile", bits=32, model=m)


def test_query_kinds_constant_in_sync():
    assert set(QUERY_KINDS) == set(selection.QUERY_KINDS)
