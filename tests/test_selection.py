"""Cost-model subsystem: CostModel round-trip, cost-function sanity
(monotonicity, the fixed p-way sample-volume term of SSort), regime
structure under parameterized profiles, and the calibrate.py fitter."""
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import selection
from repro.core.selection import CostModel

ALL_COSTS = {
    "gatherm": selection.cost_gatherm,
    "allgatherm": selection.cost_allgatherm,
    "rfis": selection.cost_rfis,
    "rquick": selection.cost_rquick,
    "rams": selection.cost_rams,
    "bitonic": selection.cost_bitonic,
    "ssort": selection.cost_ssort,
}


# ---------------------------------------------------------------------------
# CostModel dataclass + JSON round-trip
# ---------------------------------------------------------------------------


def test_cost_model_json_roundtrip(tmp_path):
    m = CostModel(name="unit", alpha=3e-6, alpha_c=7e-6, alpha_hop=2e-6,
                  beta=9e-11, local_rate=1.5e9, slot_overhead=2.0,
                  meta={"fit": {"r2": 0.97}})
    path = m.save(str(tmp_path / "sub" / "unit.json"))
    loaded = CostModel.load(path)
    assert loaded == m
    assert loaded.meta["fit"]["r2"] == 0.97


def test_cost_model_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown CostModel fields"):
        CostModel.from_json('{"name": "x", "gamma": 1.0}')


def test_partition_rate_roundtrips_and_defaults():
    m = CostModel(name="unit", local_rate=2e9, partition_rate=8e9)
    loaded = CostModel.from_json(m.to_json())
    assert loaded == m and loaded.part_rate == 8e9
    # profiles written before the fused partition kernel have no
    # partition_rate key: they must still load, falling back to local_rate
    old = CostModel.from_json('{"name": "pre-partition", "local_rate": 3e9}')
    assert old.partition_rate is None
    assert old.part_rate == 3e9


def test_partition_rate_lowers_partition_heavy_costs():
    """A faster partition rate must cut exactly the partition term: rams,
    rquick and ssort get cheaper; gatherm (no partition work) is
    unchanged."""
    base = CostModel(name="b", local_rate=2e9)
    fast = CostModel(name="f", local_rate=2e9, partition_rate=1e12)
    n, p = 2**24, 256
    for fn in (selection.cost_rams, selection.cost_rquick,
               selection.cost_ssort):
        assert fn(n, p, model=fast) < fn(n, p, model=base)
    assert selection.cost_gatherm(n, p, model=fast) == \
        selection.cost_gatherm(n, p, model=base)
    # nested-mesh rams pays the same split
    assert selection.cost_rams(n, p, model=fast, mesh_shape=(32, 8)) < \
        selection.cost_rams(n, p, model=base, mesh_shape=(32, 8))


def test_default_profile_matches_priors():
    m = selection.DEFAULT_MODEL
    assert m.alpha == 2.0e-6 and m.alpha_c == 5.0e-6
    assert m.beta == pytest.approx(4 / 50e9)
    # cost functions default to the prior profile
    assert selection.cost_rquick(2**20, 256) == \
        selection.cost_rquick(2**20, 256, model=m)


# ---------------------------------------------------------------------------
# Cost-function sanity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ALL_COSTS))
@pytest.mark.parametrize("p", [64, 4096, 2**18])
def test_costs_positive_and_monotone_in_n(name, p):
    fn = ALL_COSTS[name]
    grid = [max(1, p // 64), p, 8 * p, 64 * p, 2**10 * p, 2**16 * p]
    costs = [fn(n, p) for n in grid]
    assert all(c > 0 for c in costs)
    assert all(b >= a for a, b in zip(costs, costs[1:])), \
        f"{name} not monotone in n at p={p}: {costs}"


def test_ssort_pays_p_way_sample_volume():
    """Regression for the degenerate `16·lg(p)·p/p` term: the all-gathered
    sample volume is Θ(p log p) words *per PE*, so at fixed n/p the SSort
    wire term must grow superlinearly with p — the paper's
    n = Ω(p²/log p) efficiency bound."""
    npp = 64
    costs = [selection.cost_ssort(npp * p, p) for p in (64, 1024, 2**14, 2**18)]
    assert all(b > a for a, b in zip(costs, costs[1:]))
    # at massive p the sample volume alone dwarfs RAMS entirely
    p = 2**18
    assert selection.cost_ssort(npp * p, p) > 5 * selection.cost_rams(npp * p, p)
    # the wire term dominates scaling: doubling p at fixed n/p must cost
    # more than the pre-fix (constant 16·lg p) version could explain
    m = selection.DEFAULT_MODEL
    delta = selection.cost_ssort(npp * 2**15, 2**15) \
        - selection.cost_ssort(npp * 2**14, 2**14)
    assert delta > m.beta * 16 * 14 * 2**14   # ≥ β·(new samples volume)/2


# ---------------------------------------------------------------------------
# Regime structure (paper §IV / Table I)
# ---------------------------------------------------------------------------


def _winners(rows):
    seq = []
    for _, _, algo in rows:
        if not seq or seq[-1] != algo:
            seq.append(algo)
    return seq


def test_regime_table_four_regimes_default_profile():
    rows = selection.regime_table(2**18, range(-8, 24))
    assert _winners(rows) == ["gatherm", "rfis", "rquick", "rams"]


def test_regime_table_honors_custom_profile():
    # make point-to-point steps catastrophically expensive: the fused-
    # collective algorithm (RAMS) must take over the mid regime too
    m = CostModel(name="slow-p2p", alpha=1.0, alpha_c=5e-6, alpha_hop=1.5e-6,
                  beta=8e-11, local_rate=2e9)
    rows = selection.regime_table(2**18, range(4, 24), model=m)
    assert all(a == "rams" for _, _, a in rows)

    # free wire, free launches except fused: hypercube algorithms win
    m2 = CostModel(name="fused-costly", alpha=1e-9, alpha_c=10.0,
                   alpha_hop=1.0, beta=8e-11, local_rate=2e9)
    rows2 = selection.regime_table(2**18, range(4, 24), model=m2)
    assert "rams" not in {a for _, _, a in rows2}


def test_select_algorithm_accepts_model_kwarg():
    p = 2**18
    assert selection.select_algorithm(2**20 * p, p,
                                      model=selection.DEFAULT_MODEL) == "rams"


# ---------------------------------------------------------------------------
# The calibrate.py profile fitter (pure function, synthetic data)
# ---------------------------------------------------------------------------


def _import_calibrate():
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    import calibrate
    return calibrate


def test_fit_profile_recovers_known_machine():
    cal = _import_calibrate()
    rng = np.random.default_rng(5)
    theta = np.array([2.5e-6, 6e-6, 1.2e-6, 9e-11, 4e-10])
    cells = []
    for _ in range(40):
        f = {
            "p2p": int(rng.integers(1, 200)),
            "fused": int(rng.integers(1, 30)),
            "hops": float(rng.uniform(1, 100)),
            "wire_words": float(rng.uniform(1e3, 1e7)),
            "local_words": float(rng.uniform(1e3, 1e7)),
        }
        feats = np.array([f[k] for k in cal._FEATURES])
        cells.append({**f, "seconds": float(feats @ theta)})
    model = cal.fit_profile(cells, "synthetic")
    got = np.array([model.alpha, model.alpha_c, model.alpha_hop, model.beta,
                    1.0 / model.local_rate])
    np.testing.assert_allclose(got, theta, rtol=1e-4)
    assert model.meta["fit"]["r2"] > 0.999
    assert model.name == "synthetic"
    # fitted profiles feed straight back into selection
    assert selection.select_algorithm(2**20 * 2**18, 2**18,
                                      model=model) == "rams"


def test_measure_profile_microbench_smoke():
    """The microbenchmark path produces a positive, JSON-round-trippable
    profile (tiny p: this only checks plumbing, not realistic constants)."""
    cal = _import_calibrate()
    m = cal.measure_profile([8], "micro-smoke")
    assert m.alpha > 0 and m.alpha_c > 0 and m.alpha_hop > 0
    assert m.beta > 0 and m.local_rate > 0
    assert m.meta["microbench"]["p"] == [8]
    m2 = CostModel.from_json(m.to_json())
    assert m2 == m
    assert selection.select_algorithm(8, 8, model=m2) in \
        ("gatherm", "rfis", "rquick", "rams")


def test_fit_profile_floors_unidentified_parameters():
    cal = _import_calibrate()
    # every cell has zero fused collectives: α_c / α_hop unidentifiable
    cells = [{"p2p": k, "fused": 0, "hops": 0.0, "wire_words": 100.0 * k,
              "local_words": 10.0 * k, "seconds": 2e-6 * k + 8e-9 * k}
             for k in range(1, 30)]
    model = cal.fit_profile(cells, "degenerate")
    assert model.alpha_c > 0 and model.alpha_hop > 0
    assert model.alpha > 0 and model.local_rate > 0
