"""Differential test matrix: every algorithm vs ``np.sort`` across the
paper's input distributions × PE counts × execution backends.

Contract per cell (check_sort): output equals np.sort(input) exactly,
the ``idx`` payload is a permutation (no element lost or duplicated), and
overflow == 0.  The non-robust ssort is exercised only on the instances the
paper says it handles (its duplicate-key failure is asserted separately in
test_sorting.py).

The fast lane runs a core instance set covering duplicate-heavy (Zero,
g-Group) and skewed (Staggered) inputs at p ∈ {2, 4, 8}; the remaining
instances and the p = 64 sim sweep are marked ``slow``.
"""
import numpy as np
import pytest

from repro.core.api import SortConfig, psort
from repro.data.distributions import INSTANCES, generate_instance
from helpers import check_sort

ROBUST = ["rquick", "rfis", "rams", "bitonic"]
GATHER = ["gatherm", "allgatherm"]
ALL_ALGOS = ROBUST + ["ssort"] + GATHER
ALL_INSTANCES = sorted(INSTANCES)
CORE_INSTANCES = ["Uniform", "Zero", "g-Group", "Staggered"]
# heavy duplicates overflow classical sample sort's static slots (paper
# §VII-B); exercising ssort there is the negative test in test_sorting.py.
# Mirrored joins them at small p: the bit-reversed PE's value range
# 2^31//(mi+1) collapses to one key, i.e. n/p duplicates of one value.
SSORT_INSTANCES = [i for i in ALL_INSTANCES
                   if i not in ("Zero", "DeterDupl", "RandDupl", "Mirrored")]


def _cells(algos, instances):
    for algorithm in algos:
        for instance in ALL_INSTANCES:
            if instance not in instances:
                continue
            marks = [] if instance in CORE_INSTANCES else [pytest.mark.slow]
            yield pytest.param(algorithm, instance, marks=marks,
                               id=f"{algorithm}-{instance}")


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("algorithm,instance", _cells(ROBUST, ALL_INSTANCES))
def test_robust_matrix(algorithm, instance, p):
    x = generate_instance(instance, p, 37 * p).astype(np.int32)
    check_sort(x, p, algorithm)


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("algorithm,instance", _cells(["ssort"], SSORT_INSTANCES))
def test_ssort_matrix(algorithm, instance, p):
    x = generate_instance(instance, p, 37 * p).astype(np.int32)
    check_sort(x, p, algorithm)


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("algorithm,instance", _cells(GATHER, ALL_INSTANCES))
def test_gather_matrix(algorithm, instance, p):
    x = generate_instance(instance, p, 9 * p).astype(np.int32)
    check_sort(x, p, algorithm)


# ---------------------------------------------------------------------------
# Backend equivalence: sim must match shard_map bit for bit at p = 8.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_sim_matches_shard_map_bitwise(algorithm):
    p = 8
    x = generate_instance("Uniform", p, 53 * p, seed=11).astype(np.int32)
    cfg = SortConfig(p=p, algorithm=algorithm)
    out_sm, info_sm = psort(x, config=cfg, return_info=True)
    out_sim, info_sim = psort(x, config=cfg.replace(backend="sim"),
                              return_info=True)
    assert (np.asarray(out_sm) == np.asarray(out_sim)).all()
    assert (info_sm["perm"] == info_sim["perm"]).all()
    assert (info_sm["counts"] == info_sim["counts"]).all()
    assert info_sm["overflow"] == info_sim["overflow"] == 0


# ---------------------------------------------------------------------------
# High emulated PE counts on the sim backend — beyond the 8 XLA host
# devices.  p = 64 for every algorithm (the acceptance bar); the instance
# sweep and p = 256 ride in the slow lane.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_sim_p64_all_algorithms(algorithm):
    p = 64
    x = generate_instance("Uniform", p, 48 * p, seed=5).astype(np.int32)
    out = psort(x, config=SortConfig(p=p, algorithm=algorithm,
                                     backend="sim"))
    assert (np.asarray(out) == np.sort(x)).all()


@pytest.mark.slow
@pytest.mark.parametrize("instance", ALL_INSTANCES)
@pytest.mark.parametrize("algorithm", ROBUST)
def test_sim_p64_robust_instances(algorithm, instance):
    p = 64
    x = generate_instance(instance, p, 24 * p).astype(np.int32)
    check_sort(x, p, algorithm, backend="sim")


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["rquick", "rams"])
def test_sim_p256_scaling_smoke(algorithm):
    p = 256
    x = generate_instance("Uniform", p, 32 * p).astype(np.int32)
    check_sort(x, p, algorithm, backend="sim")


# ---------------------------------------------------------------------------
# p = 1024 on the *chunked* sim backend: grouped collectives take the ring
# path (their one-shot gather would batch p² buffers — ~200 GB for RAMS),
# and _alltoall_route's slot assignment is sort-based.  This is the
# acceptance bar of the measurement-driven-cost-model PR.
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("instance", ["Uniform", "Zero", "Staggered"])
@pytest.mark.parametrize("algorithm", ["rquick", "rams"])
def test_sim_p1024_chunked_matrix(algorithm, instance):
    p = 1024
    x = generate_instance(instance, p, 24 * p).astype(np.int32)
    check_sort(x, p, algorithm, backend="sim")


@pytest.mark.slow
def test_sim_p1024_auto_uses_measured_structure():
    """algorithm='auto' at p = 1024 still sorts correctly whichever regime
    the (default or custom) profile selects."""
    from repro.core.selection import CostModel
    p = 1024
    x = generate_instance("Uniform", p, 8 * p).astype(np.int32)
    out, info = psort(x, config=SortConfig(p=p, algorithm="auto",
                                           backend="sim",
                                           cost_model=CostModel(name="t")),
                      return_info=True)
    assert (np.asarray(out) == np.sort(x)).all()
    assert info["algorithm"] in ("gatherm", "rfis", "rquick", "rams")


def test_sim_rejects_bad_args():
    x = np.arange(16, dtype=np.int32)
    with pytest.raises(ValueError):
        psort(x, config=SortConfig(algorithm="rquick",
                                   backend="sim"))        # p required
    with pytest.raises(ValueError):
        psort(x, config=SortConfig(p=4, algorithm="rquick",
                                   backend="nope"))       # unknown backend
