"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.bitonic import local_sort_fast, merge_tiles, sort_tile
from repro.kernels.bitonic.ref import merge_tiles_ref, sort_tile_ref
from repro.kernels.kway import kway_classify
from repro.kernels.kway.ref import kway_classify_ref


@pytest.mark.parametrize("n", [128, 256, 512, 2048, 8192])
@pytest.mark.parametrize("gen", ["uniform", "dup", "zero", "sorted", "rev"])
def test_sort_kernel_shapes(n, gen, rng):
    if gen == "uniform":
        k = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    elif gen == "dup":
        k = rng.integers(0, 3, size=n).astype(np.uint32)
    elif gen == "zero":
        k = np.zeros(n, np.uint32)
    elif gen == "sorted":
        k = np.sort(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    else:
        k = np.sort(rng.integers(0, 2**32, size=n, dtype=np.uint32))[::-1].copy()
    out = np.asarray(sort_tile(jnp.asarray(k)))
    np.testing.assert_array_equal(out, np.asarray(sort_tile_ref(jnp.asarray(k))))


@pytest.mark.parametrize("n", [128, 1024])
def test_sort_kernel_payload_is_permutation(n, rng):
    k = rng.integers(0, 16, size=n).astype(np.uint32)   # heavy ties
    v = np.arange(n, dtype=np.uint32)
    ok, ov = sort_tile(jnp.asarray(k), jnp.asarray(v))
    ok, ov = np.asarray(ok), np.asarray(ov)
    np.testing.assert_array_equal(ok, np.sort(k))
    assert len(np.unique(ov)) == n
    np.testing.assert_array_equal(k[ov], ok)            # pairs stay together


@pytest.mark.parametrize("n", [128, 512, 4096])
def test_merge_kernel(n, rng):
    a = np.sort(rng.integers(0, 10**6, size=n)).astype(np.uint32)
    b = np.sort(rng.integers(0, 10**6, size=n)).astype(np.uint32)
    out = np.asarray(merge_tiles(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(
        out, np.asarray(merge_tiles_ref(jnp.asarray(a), jnp.asarray(b))))


def test_multi_tile_sort(monkeypatch, rng):
    import repro.kernels.bitonic.ops as ops
    monkeypatch.setattr(ops, "MAX_TILE", 512)
    k = rng.integers(0, 2**32, size=8192, dtype=np.uint32)
    out = np.asarray(ops.local_sort_fast(jnp.asarray(k)))
    np.testing.assert_array_equal(out, np.sort(k))


def test_fallback_small_and_odd_sizes(rng):
    for n in (1, 7, 100):
        k = rng.integers(0, 1000, size=n).astype(np.uint32)
        out = local_sort_fast(jnp.asarray(k))
        np.testing.assert_array_equal(np.asarray(out), np.sort(k))


@pytest.mark.parametrize("n", [100, 200, 5000])
def test_sort_kernel_non_pow2_sizes(n, rng):
    """Non-power-of-two inputs take the kernel path via pad-to-pow2."""
    from repro.kernels.bitonic import ops
    assert ops.supported(n, jnp.uint32)
    k = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    out = np.asarray(local_sort_fast(jnp.asarray(k)))
    np.testing.assert_array_equal(out, np.sort(k))


def test_sort_kernel_non_pow2_payload(rng):
    n = 300
    k = rng.integers(0, 50, size=n).astype(np.uint32)   # heavy ties
    v = np.arange(n, dtype=np.uint32)
    ok, ov = local_sort_fast(jnp.asarray(k), jnp.asarray(v))
    ok, ov = np.asarray(ok), np.asarray(ov)
    np.testing.assert_array_equal(ok, np.sort(k))
    assert len(np.unique(ov)) == n                      # no pad payload leaked
    np.testing.assert_array_equal(k[ov], ok)            # pairs stay together


def test_sort_kernel_pad_val_override(rng):
    """A caller-chosen pad value (absent from but ≥ the data — the
    documented escape hatch for max-key payloads) keeps pads at the back
    and the payload a clean permutation."""
    n = 200
    k = rng.integers(0, 4, size=n).astype(np.uint32)
    v = np.arange(n, dtype=np.uint32)
    ok, ov = local_sort_fast(jnp.asarray(k), jnp.asarray(v),
                             pad_val=np.uint32(5))
    ok, ov = np.asarray(ok), np.asarray(ov)
    np.testing.assert_array_equal(ok, np.sort(k))
    assert len(np.unique(ov)) == n
    np.testing.assert_array_equal(k[ov], ok)


@pytest.mark.parametrize("nb", [2, 8, 64, 128])
@pytest.mark.parametrize("C", [8192, 16384])
def test_kway_classifier_sweep(nb, C, rng):
    keys = rng.integers(0, 1000, size=C).astype(np.uint32)
    ties = rng.integers(0, 2**20, size=C).astype(np.uint32)
    sk = np.sort(rng.integers(0, 1000, size=nb - 1)).astype(np.uint32)
    st = rng.integers(0, 2**20, size=nb - 1).astype(np.uint32)
    b1, h1 = kway_classify(jnp.asarray(keys), jnp.asarray(ties),
                           jnp.asarray(sk), jnp.asarray(st), n_buckets=nb)
    b2, h2 = kway_classify_ref(jnp.asarray(keys), jnp.asarray(ties),
                               jnp.asarray(sk), jnp.asarray(st), n_buckets=nb)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert int(np.asarray(h1).sum()) == C


def test_kway_tie_breaking_splits_equal_keys(rng):
    """All-equal keys must still split by the tie component (App. G)."""
    C, nb = 8192, 8
    keys = np.zeros(C, np.uint32)
    ties = np.arange(C, dtype=np.uint32)
    qs = np.linspace(0, C, nb, endpoint=False)[1:].astype(np.uint32)
    b, h = kway_classify(jnp.asarray(keys), jnp.asarray(ties),
                         jnp.asarray(np.zeros(nb - 1, np.uint32)),
                         jnp.asarray(qs), n_buckets=nb)
    h = np.asarray(h)
    assert h.max() - h.min() <= 1          # perfectly balanced buckets


def test_pallas_local_sort_inside_rquick(monkeypatch, rng):
    """End-to-end: the distributed RQuick with the Pallas local-sort kernel
    on the hot path (interpret mode) must equal np.sort."""
    from repro.core.api import SortConfig, psort
    monkeypatch.setenv("REPRO_PALLAS_LOCAL_SORT", "1")
    x = rng.integers(0, 10, size=512).astype(np.int32)   # heavy duplicates
    out, info = psort(x, config=SortConfig(p=4, algorithm="rquick"),
                      return_info=True)
    assert (np.asarray(out) == np.sort(x)).all()
    assert info["overflow"] == 0


def test_pallas_flag_busts_psort_jit_cache(monkeypatch, rng):
    """Toggling REPRO_PALLAS_LOCAL_SORT between same-signature psort calls
    must retrace (the flag is a jit cache key), not silently reuse the
    kernel-less executable."""
    import repro.kernels.bitonic as kb
    from repro.core.api import SortConfig, psort
    # n=512, p=4 → capacity 256: a power of two, so the kernel gate
    # (kernels.bitonic.supported) accepts the shard
    x = rng.integers(0, 1 << 20, size=512).astype(np.int32)

    monkeypatch.delenv("REPRO_PALLAS_LOCAL_SORT", raising=False)
    cfg = SortConfig(p=4, algorithm="bitonic", backend="sim")
    out_plain = psort(x, config=cfg)

    called = []
    real = kb.local_sort_fast
    monkeypatch.setattr(kb, "local_sort_fast",
                        lambda *a: (called.append(1), real(*a))[1])
    monkeypatch.setenv("REPRO_PALLAS_LOCAL_SORT", "1")
    out_pallas = psort(x, config=cfg)
    assert called, "flag flip did not retrace: Pallas kernel never traced"
    assert (np.asarray(out_pallas) == np.asarray(out_plain)).all()
