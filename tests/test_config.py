"""SortConfig: the consolidated psort surface and its deprecation shim.

Satellite contract of the overlap PR: ``psort(keys, config=SortConfig(...))``
is the primary signature; every legacy flat-kwarg spelling still works but
emits **exactly one** DeprecationWarning per call and produces bitwise
identical output; mixing the styles is a TypeError.  The config is frozen
and hashable (it keys psort's jit cache) and round-trips through
``from_kwargs`` / ``replace``.
"""
import warnings

import numpy as np
import pytest

from repro.core.api import SortConfig, psort, trace_collectives
from repro.core.selection import CostModel, select_algorithm
from repro.data.distributions import generate_instance


def _legacy_call(fn, *args, **kw):
    """Run a deliberately legacy-style call, swallowing its warning (the
    suite runs under -W error::DeprecationWarning in the CI deprecation
    lane — these are the only sanctioned legacy call sites)."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*args, **kw)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    return out, dep


# ---------------------------------------------------------------------------
# The dataclass itself.
# ---------------------------------------------------------------------------


def test_config_frozen_hashable_and_replace():
    cfg = SortConfig(p=8, algorithm="rams", backend="sim")
    with pytest.raises(AttributeError):
        cfg.p = 4
    assert hash(cfg) == hash(SortConfig(p=8, algorithm="rams", backend="sim"))
    cfg2 = cfg.replace(overlap=True)
    assert cfg2.overlap and not cfg.overlap and cfg2.p == 8
    assert cfg != cfg2


def test_config_from_kwargs_splits_algo_kw():
    cfg = SortConfig.from_kwargs(p=8, algorithm="rams", levels=2,
                                 level_bits=(2, 1))
    assert cfg.p == 8 and cfg.levels == 2
    # non-field kwargs land in algo_kw, normalized to sorted pairs
    assert dict(cfg.algo_kw) == {"level_bits": (2, 1)}
    # dict-style algo_kw normalizes to the same hashable tuple
    assert cfg == SortConfig(p=8, algorithm="rams", levels=2,
                             algo_kw={"level_bits": [2, 1]})


def test_config_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        SortConfig(backend="nope")


def test_cost_model_overlap_range_checked_at_load():
    with pytest.raises(ValueError, match="overlap"):
        CostModel(overlap=1.5)
    with pytest.raises(ValueError, match="overlap"):
        CostModel(overlap=-0.1)
    assert CostModel(overlap=0.0).overlap == 0.0
    assert CostModel(overlap=1.0).overlap == 1.0
    # the JSON loader goes through __post_init__ too
    with pytest.raises(ValueError, match="overlap"):
        CostModel.from_json(
            CostModel().to_json().replace('"overlap": 0.0', '"overlap": 2.0'))


# ---------------------------------------------------------------------------
# The deprecation shim.
# ---------------------------------------------------------------------------


def test_legacy_psort_warns_once_and_matches_bitwise():
    x = generate_instance("Staggered", 8, 32 * 8, seed=3).astype(np.int32)
    (out_l, info_l), dep = _legacy_call(
        psort, x, p=8, algorithm="rquick", backend="sim", return_info=True)
    assert len(dep) == 1, [str(w.message) for w in dep]
    assert "SortConfig" in str(dep[0].message)
    out_c, info_c = psort(x, config=SortConfig(p=8, algorithm="rquick",
                                               backend="sim"),
                          return_info=True)
    assert (np.asarray(out_l) == np.asarray(out_c)).all()
    assert (info_l["perm"] == info_c["perm"]).all()
    assert info_l["overflow"] == info_c["overflow"] == 0


def test_legacy_positional_p_still_works():
    x = np.arange(64, dtype=np.int32)[::-1].copy()
    (out, _), dep = _legacy_call(psort, x, 4, algorithm="rquick",
                                 backend="sim", return_info=True)
    assert len(dep) == 1
    assert (np.asarray(out) == np.sort(x)).all()


def test_mixing_config_and_legacy_kwargs_is_an_error():
    x = np.arange(16, dtype=np.int32)
    with pytest.raises(TypeError, match="legacy"):
        psort(x, config=SortConfig(p=4, backend="sim"), algorithm="rquick")
    with pytest.raises(TypeError, match="SortConfig"):
        psort(x, config={"p": 4})


def test_config_style_emits_no_warning():
    x = np.arange(32, dtype=np.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        out = psort(x, config=SortConfig(p=4, algorithm="rquick",
                                         backend="sim"))
    assert (np.asarray(out) == np.sort(x)).all()


def test_legacy_trace_collectives_matches_config_style():
    t_c = trace_collectives(256, SortConfig(p=8, algorithm="rams"))
    t_l, dep = _legacy_call(trace_collectives, 256, 8, "rams")
    assert len(dep) == 1
    assert t_l.summary() == t_c.summary()


def test_select_algorithm_accepts_config():
    cfg = SortConfig(p=1024)
    assert select_algorithm(2**20 * 1024, config=cfg) == \
        select_algorithm(2**20 * 1024, 1024) == "rams"
    # direct args override config fields
    assert select_algorithm(max(1, 1024 // 243), config=cfg) == "gatherm"


def test_sort_service_accepts_config():
    from repro.launch.sort_serve import SortService
    keys = generate_instance("Uniform", 4, 256, seed=5).astype(np.int64)
    svc = SortService(keys, config=SortConfig(p=4, backend="sim"))
    assert svc.config.p == 4
    with pytest.raises(ValueError, match="inconsistent"):
        SortService(keys, p=8, config=SortConfig(p=4))
    with pytest.raises(ValueError, match="p"):
        SortService(keys, config=SortConfig())
