"""The serving layer: continuous-batching SortService semantics, the
None-safe latency stats, and the (batch, 1) next-token feed contract."""
import numpy as np
import pytest

from repro.launch.serve import next_token_input
from repro.launch.sort_serve import (Request, SortService, latency_stats,
                                     main as serve_main, parse_mix)

import jax.numpy as jnp


# -- latency_stats (shared by both serving drivers) -------------------------


def test_latency_stats_normal():
    st = latency_stats([0.5, 0.010, 0.010, 0.010], warmup=1, rate_scale=8)
    assert st["n"] == 3
    assert st["p50_ms"] == pytest.approx(10.0)
    assert st["per_s"] == pytest.approx(800.0)


@pytest.mark.parametrize("lat", [[], [0.5]])
def test_latency_stats_guards_tiny_samples(lat):
    """tokens=1 / empty runs must not report compile-time as a percentile:
    all stats come back None with an explanatory note."""
    st = latency_stats(lat, warmup=1)
    assert st["p50_ms"] is None and st["p99_ms"] is None
    assert st["per_s"] is None
    assert "warmup" in st["note"]
    assert st["n"] == len(lat)


def test_latency_stats_warmup_zero_keeps_single_sample():
    st = latency_stats([0.020], warmup=0)
    assert st["p50_ms"] == pytest.approx(20.0)


# -- next-token feed contract (launch/serve.py bugfix) ----------------------


def test_next_token_input_contract():
    flat = jnp.array([3, 1, 4, 1])
    out = next_token_input(flat, 4)
    assert out["tokens"].shape == (4, 1)
    assert out["tokens"].dtype == jnp.int32
    col = jnp.array([[3], [1], [4], [1]])
    assert next_token_input(col, 4)["tokens"].shape == (4, 1)
    # multi-head sampler output is ambiguous — must be rejected, not
    # silently sliced (the old reshape fed head-interleaved garbage)
    with pytest.raises(ValueError, match="next-token contract"):
        next_token_input(jnp.zeros((4, 2), jnp.int32), 4)
    with pytest.raises(ValueError, match="next-token contract"):
        next_token_input(jnp.zeros((8,), jnp.int32), 4)


# -- SortService ------------------------------------------------------------


@pytest.fixture(scope="module")
def svc_and_oracle():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 1 << 20, size=2048).astype(np.int64)
    return keys, np.sort(keys)


def _mk(keys, **kw):
    kw.setdefault("backend", "sim")
    return SortService(keys, 8, **kw)


def test_service_micro_batches_by_head_kind(svc_and_oracle):
    keys, _ = svc_and_oracle
    svc = _mk(keys, policy="selection")
    svc.submit("top_k", 3)
    svc.submit("top_k", 5)
    svc.submit("percentile", 50.0)
    svc.submit("top_k", 7)
    done = svc.step()
    # one launch answers every queued top_k (FIFO), skipping the
    # percentile; batch barrier → identical step latency for the group
    assert [r.request.arg for r in done] == [3, 5, 7]
    assert len({r.step_s for r in done}) == 1
    assert all(r.batch == 3 for r in done)
    assert [r.kind for r in svc.queue] == ["percentile"]
    done2 = svc.step()
    assert [r.request.kind for r in done2] == ["percentile"]
    assert svc.step() == []                      # empty queue is a no-op


def test_service_answers_match_oracle(svc_and_oracle):
    keys, srt = svc_and_oracle
    n = len(keys)
    for policy in ("selection", "fullsort"):
        svc = _mk(keys, policy=policy)
        ids = [svc.submit("top_k", 10), svc.submit("percentile", 25.0),
               svc.submit("rank_of_key", int(keys[3])),
               svc.submit("range_query", (int(srt[10]), int(srt[100])))]
        out = {r.request.id: r for r in svc.drain()}
        assert (np.asarray(out[ids[0]].value) == srt[-10:]).all()
        assert out[ids[1]].value == srt[int(np.floor(0.25 * (n - 1)))]
        assert out[ids[2]].value == (
            int(np.searchsorted(srt, keys[3], "left")),
            int(np.searchsorted(srt, keys[3], "right")))
        assert out[ids[3]].value == 90
        assert all(r.path == policy for r in out.values())


def test_service_sort_requests_and_fullsort_cache(svc_and_oracle):
    keys, srt = svc_and_oracle
    svc = _mk(keys)
    svc.submit("sort")
    svc.submit("sort")
    done = svc.drain()
    assert all((np.asarray(r.value) == srt).all() for r in done)
    assert all(r.path == "sort" for r in done)
    assert svc._sorted is not None               # cached, built once


def test_service_auto_policy_consults_cost_model(svc_and_oracle):
    keys, _ = svc_and_oracle
    svc = _mk(keys, policy="auto")
    # n=2048 at p=8 is deep inside the sort-wins regime of cost_select
    assert svc.route("top_k", 1) in ("selection", "fullsort")
    svc.submit("top_k", 4)
    (r,) = svc.drain()
    assert r.path in ("selection", "fullsort")


def test_service_stats_and_guards(svc_and_oracle):
    keys, _ = svc_and_oracle
    svc = _mk(keys, policy="selection")
    assert svc.stats() == {}                     # nothing completed yet
    svc.submit("top_k", 2)
    svc.drain()
    st = svc.stats()
    # a single request <= warmup → guarded None stats, not fake numbers
    assert st["top_k"]["p50_ms"] is None and "note" in st["top_k"]
    for _ in range(5):
        svc.submit("top_k", 2)
    svc.drain()
    st = svc.stats()
    assert st["top_k"]["p50_ms"] is not None
    assert st["overall"]["queries_per_s"] > 0


def test_service_validation():
    svc = _mk(np.arange(64, dtype=np.int32))
    with pytest.raises(ValueError, match="query kind"):
        svc.submit("argmax")
    with pytest.raises(ValueError, match="policy"):
        _mk(np.arange(64, dtype=np.int32), policy="always")
    with pytest.raises(ValueError, match="query kind"):
        parse_mix("top_k=1,bogus=2")
    assert parse_mix("top_k=4,sort") == {"top_k": 4, "sort": 1}


def test_cli_smoke(capsys):
    serve_main(["--smoke", "--queries", "12", "--seed", "1"])
    out = capsys.readouterr().out
    assert "[sort_serve]" in out and "12 queries" in out
