"""Hierarchical nested-axis meshes: the (inter × intra) contract.

Acceptance bar of the nested-axis PR: ``psort(mesh_shape=(p_o, p_i))``
runs every AMS level's grouped collectives over a *named* axis of a nested
mesh — the first level's all_to_all is the only level exchange crossing
the slow outer axis — and is **bitwise identical** to the flat
``axis_index_groups`` path at the same total p and level schedule, on both
backends (shard_map over a real (inter, intra) device mesh; sim via
``sim_map(nested=...)``).  Plus the grouped-collective edge cases *under*
the nested view (single-member outer axis, strided inner-axis groups,
forced ring chunking across the outer axis) and the counted-trace
attribution invariants (per-level tags partition the totals; inter vs.
intra split).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import comm, selection
from repro.core.api import SortConfig, psort, trace_collectives
from repro.core.rams import nested_level_bits
from repro.data.distributions import generate_instance
from repro.dist.sharding import sort_mesh

DISTS = ["Uniform", "Zero", "Staggered", "DeterDupl"]


def _assert_nested_matches_flat(x, p_o, p_i, algorithm, backend,
                                levels=None):
    """Nested run ≡ flat run of the same level schedule (keys, perm,
    counts, overflow) — the bitwise-identity acceptance bar."""
    p = p_o * p_i
    cfg_n = SortConfig(mesh_shape=(p_o, p_i), algorithm=algorithm,
                       backend=backend, levels=levels)
    out_n, info_n = psort(x, config=cfg_n, return_info=True)
    kw = {}
    if algorithm == "rams":
        kw["level_bits"] = tuple(nested_level_bits(p_o, p_i, levels))
    cfg_f = SortConfig(p=p, algorithm=algorithm, backend=backend, algo_kw=kw)
    out_f, info_f = psort(x, config=cfg_f, return_info=True)
    assert info_n["overflow"] == 0, (algorithm, backend)
    assert info_n["mesh_shape"] == (p_o, p_i)
    assert (np.asarray(out_n) == np.asarray(out_f)).all(), \
        (algorithm, backend)
    assert (info_n["perm"] == info_f["perm"]).all(), (algorithm, backend)
    assert (info_n["counts"] == info_f["counts"]).all(), (algorithm, backend)
    assert (np.asarray(out_n) == np.sort(np.asarray(x), axis=-1)).all()


# ---------------------------------------------------------------------------
# Acceptance: bitwise identity nested vs. flat.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["rams", "rquick", "ssort", "bitonic",
                                       "rfis", "gatherm", "allgatherm"])
def test_shard_map_2x4_nested_bitwise_vs_flat(algorithm):
    x = generate_instance("Uniform", 8, 37 * 8, seed=3).astype(np.int32)
    _assert_nested_matches_flat(x, 2, 4, algorithm, "shard_map")


@pytest.mark.parametrize("dist", DISTS)
def test_sim_4x16_nested_rams_bitwise_vs_flat(dist):
    p = 64
    x = generate_instance(dist, p, 24 * p, seed=5).astype(np.int32)
    _assert_nested_matches_flat(x, 4, 16, "rams", "sim")


def test_sim_nested_rquick_bitwise_vs_flat():
    p = 64
    x = generate_instance("Staggered", p, 16 * p, seed=9).astype(np.int32)
    _assert_nested_matches_flat(x, 8, 8, "rquick", "sim")


@pytest.mark.slow
@pytest.mark.parametrize("dist", ["Uniform", "Gaussian", "BucketSorted",
                                  "g-Group", "Zero", "DeterDupl",
                                  "RandDupl", "Staggered", "Mirrored",
                                  "AllToOne", "Reverse"])
def test_sim_16x64_nested_rams_bitwise_vs_flat(dist):
    """The full distribution suite at the 16×64 = 1024-PE sim mesh."""
    p = 1024
    x = generate_instance(dist, p, 4 * p, seed=7).astype(np.int32)
    _assert_nested_matches_flat(x, 16, 64, "rams", "sim")


def test_batched_nested_rows_match_unbatched():
    """2-D keys over a (data, inter, intra) mesh: row r ≡ 1-D nested run."""
    d, p_o, p_i = 2, 2, 2
    xs = np.stack([generate_instance("Uniform", 4, 11 * 4, seed=13 + r)
                   .astype(np.int32) for r in range(d)])
    cfg = SortConfig(mesh_shape=(p_o, p_i), algorithm="rams")
    out = np.asarray(psort(xs, config=cfg))
    for r in range(d):
        ref = np.asarray(psort(xs[r], config=cfg))
        assert (out[r] == ref).all()
        assert (ref == np.sort(xs[r])).all()


def test_single_member_outer_axis_is_pure_intra():
    """mesh_shape=(1, p): the whole sort lives on the intra axis and is
    bitwise the flat run; the trace shows zero outer-axis payload."""
    p = 8
    x = generate_instance("Uniform", p, 20 * p, seed=17).astype(np.int32)
    _assert_nested_matches_flat(x, 1, p, "rams", "sim")
    t = trace_collectives(20 * p, SortConfig(mesh_shape=(1, p),
                                             algorithm="rams"))
    ax = t.by_axis()
    assert ax["intra"]["wire_bytes"] > 0
    # the decomposition still launches outer-stage collectives on the
    # size-1 axis (full-axis phases), but they carry the whole payload to
    # a single participant — the intra axis does all real work.  What must
    # hold: no *level > 0* event ever names the outer axis.
    lvl_tags = [tg for tg in t.tags() if tg.startswith("level") and
                tg != "level0"]
    for tg in lvl_tags:
        assert "inter" not in t.filter(tag=tg).axes()


# ---------------------------------------------------------------------------
# Grouped-collective edge cases under the nested view.
# ---------------------------------------------------------------------------

PO, PI = 4, 4
P = PO * PI
AXES = (("inter", PO), ("intra", PI))
# strided groups on the inner axis: same non-adjacent pattern per slice
STRIDED_INNER = [[s * PI + i for i in g] for s in range(PO)
                 for g in ([0, 2], [1, 3])]
# groups spanning whole outer slices (forced across the outer axis)
OUTER_PAIRS = [[s * PI + i for s in ss for i in range(PI)]
               for ss in ([0, 1], [2, 3])]


def _grouped_body(groups, gsize):
    def fn(v):
        g = comm.all_gather(v, "sort", axis_index_groups=groups, tiled=True)
        s = comm.psum(v, "sort", axis_index_groups=groups)
        a = comm.all_to_all(jnp.tile(v, (gsize,)), "sort", split_axis=0,
                            concat_axis=0, axis_index_groups=groups,
                            tiled=True)
        return g, s, a
    return fn


def _nested_vs_flat(fn, x, chunk_bytes=None):
    impl = comm.SimCollectives(chunk_bytes=chunk_bytes) \
        if chunk_bytes is not None else None
    nest = jax.jit(comm.sim_map(fn, "sort", P, impl=impl, nested=AXES))(
        x.reshape((PO, PI) + x.shape[1:]))
    flat = jax.jit(comm.sim_map(fn, "sort", P, impl=impl))(x)
    for a, b in zip(jax.tree.leaves(nest), jax.tree.leaves(flat)):
        np.testing.assert_array_equal(
            np.asarray(a).reshape(np.asarray(b).shape), np.asarray(b))


@pytest.mark.parametrize("gname,groups", [
    ("strided_inner", STRIDED_INNER),
    ("singles", [[i] for i in range(P)]),
    ("inner_slices", [[s * PI + i for i in range(PI)] for s in range(PO)]),
    ("outer_pairs", OUTER_PAIRS),
])
def test_grouped_edge_cases_under_nested_view(gname, groups):
    x = jnp.arange(P * 3, dtype=jnp.int32).reshape(P, 3) * 5 + 2
    _nested_vs_flat(_grouped_body(groups, len(groups[0])), x)


@pytest.mark.parametrize("gname,groups", [
    ("strided_inner", STRIDED_INNER),
    ("outer_pairs", OUTER_PAIRS),
])
def test_grouped_forced_ring_under_nested_view(gname, groups):
    """chunk_bytes=0 forces the chunked ring evaluation of every grouped
    collective — across the outer axis for the outer_pairs groups."""
    x = jnp.arange(P * 3, dtype=jnp.int32).reshape(P, 3) * 5 + 2
    _nested_vs_flat(_grouped_body(groups, len(groups[0])), x, chunk_bytes=0)


def test_nested_view_rejects_misaligned_groups_and_perms():
    view = comm.NestedCollectives(comm.SIM, "sort", AXES)
    with pytest.raises(NotImplementedError):
        # group straddles an outer-slice boundary without covering it
        view._classify_groups([[0, 1, 2, 3, 4, 5], [6, 7] +
                               list(range(8, 12)), list(range(12, 16))])
    with pytest.raises(NotImplementedError):
        # permutation mixes both axes (flat +1 ring crosses slices)
        view._factor_perm([(i, (i + 1) % P) for i in range(P)])
    with pytest.raises(NotImplementedError):
        comm.NestedCollectives(comm.SIM, "sort", ((("a", 2),)))


# ---------------------------------------------------------------------------
# Counted-trace attribution.
# ---------------------------------------------------------------------------


def test_per_level_attribution_sums_to_totals():
    """The shuffle/level tags partition the nested trace — per-level
    launches and bytes sum back to the whole-trace totals."""
    t = trace_collectives(32 * 64, SortConfig(mesh_shape=(4, 16),
                                              algorithm="rams"))
    tot = t.summary()
    per_tag = t.by_tag()
    assert set(per_tag) == {"shuffle", "level0", "level1"}
    assert sum(s["launches"] for s in per_tag.values()) == tot["launches"]
    assert sum(s["wire_bytes"] for s in per_tag.values()) == \
        tot["wire_bytes"]
    per_axis = t.by_axis()
    assert set(per_axis) == {"inter", "intra"}
    assert sum(s["wire_bytes"] for s in per_axis.values()) == \
        tot["wire_bytes"]


def test_intra_levels_match_flat_trace_per_tag():
    """Levels after the first never cross the outer axis, and their events
    are identical (primitive, bytes) to the flat-axis oracle's."""
    n, p_o, p_i = 32 * 64, 4, 16
    bits = tuple(nested_level_bits(p_o, p_i))
    tn = trace_collectives(n, SortConfig(mesh_shape=(p_o, p_i),
                                         algorithm="rams"))
    tf = trace_collectives(n, SortConfig(p=p_o * p_i, algorithm="rams",
                                         algo_kw={"level_bits": bits}))
    # flat trace carries the same tags on the virtual axis
    assert tn.tags() == tf.tags()
    for tag in tn.tags():
        if tag in ("shuffle", "level0"):
            continue                       # decomposed: two-stage launches
        sub_n, sub_f = tn.filter(tag=tag), tf.filter(tag=tag)
        assert sub_n.axes() == ["intra"], tag
        assert sub_n.counts() == sub_f.counts(), tag
        assert sub_n.payload_bytes() == sub_f.payload_bytes(), tag


def test_outer_axis_carries_exactly_one_level_a2a():
    """The issue's headline invariant: the slow axis carries the shuffle
    and exactly one level's all_to_all volume — no other level."""
    t = trace_collectives(16 * 1024, SortConfig(mesh_shape=(16, 64),
                                                algorithm="rams"))
    inter_a2a = t.filter(primitive="all_to_all", axis="inter")
    assert inter_a2a.tags() == ["level0", "shuffle"]
    # one slotted exchange = 3 launches (keys, payload, per-slot counts)
    assert len(inter_a2a.filter(tag="level0").events) == 3
    # and no inter-axis events of any primitive at later levels
    later = [tg for tg in t.tags() if tg.startswith("level")
             and tg not in ("level0",)]
    assert later, "expected a multi-level schedule at 16x64"
    for tg in later:
        assert t.filter(tag=tg).axes() == ["intra"], tg


def test_trace_nested_d_invariance():
    """Adding data-axis rows leaves the per-PE nested trace unchanged."""
    cfg = SortConfig(mesh_shape=(4, 4), algorithm="rams")
    t1 = trace_collectives(32 * 16, cfg)
    t3 = trace_collectives(32 * 16, cfg, d=3)
    assert t1.summary() == t3.summary()
    assert t1.by_axis() == t3.by_axis()


# ---------------------------------------------------------------------------
# levels= through psort / regime_table; samplesort structure at levels=1.
# ---------------------------------------------------------------------------


def test_levels_plumbed_through_psort():
    p = 64
    x = generate_instance("Uniform", p, 16 * p, seed=23).astype(np.int32)
    cfg = SortConfig(p=p, algorithm="rams", backend="sim", levels=1)
    out1, i1 = psort(x, config=cfg, return_info=True)
    out2, i2 = psort(x, config=cfg.replace(levels=2), return_info=True)
    assert i1["overflow"] == 0 and i2["overflow"] == 0
    assert (np.asarray(out1) == np.sort(x)).all()
    assert (np.asarray(out2) == np.sort(x)).all()
    # the schedules differ: level counts show up in the counted traces
    t1 = trace_collectives(16 * p, SortConfig(p=p, algorithm="rams",
                                              levels=1))
    t2 = trace_collectives(16 * p, SortConfig(p=p, algorithm="rams",
                                              levels=2))
    assert set(t1.tags()) == {"shuffle", "level0"}
    assert set(t2.tags()) == {"shuffle", "level0", "level1"}
    with pytest.raises(ValueError):
        psort(x, config=SortConfig(p=p, algorithm="rquick",
                                   backend="sim", levels=2))


def test_levels1_matches_samplesort_structure():
    """One AMS level = samplesort's single-exchange structure: the counted
    traces agree on every fused collective (one sample gather; shuffle +
    exchange a2a at 3 launches each — keys, payload, slot counts).  Only
    the ppermute prefix-scan of AMS's perfect in-group balancing remains."""
    n, p = 32 * 64, 64
    tr = trace_collectives(n, SortConfig(p=p, algorithm="rams",
                                         levels=1))
    ts = trace_collectives(n, SortConfig(p=p, algorithm="ssort"))
    assert tr.counts()["all_to_all"] == ts.counts()["all_to_all"]
    assert tr.counts()["all_gather"] == ts.counts()["all_gather"] == 1
    assert set(ts.counts()) == {"all_to_all", "all_gather"}
    assert set(tr.counts()) == {"all_to_all", "all_gather", "ppermute"}


def test_regime_table_levels_and_mesh_shape():
    base = selection.regime_table(1024, range(4, 8))
    lvl1 = selection.regime_table(1024, range(4, 8), levels=1)
    nested = selection.regime_table(1024, range(4, 8),
                                    mesh_shape=(16, 64))
    assert [len(r) for r in (base, lvl1, nested)] == [4, 4, 4]
    # a cheap intra axis should only ever make RAMS *more* competitive
    m = selection.CostModel(alpha_c_inner=1e-9, beta_inner=1e-12)
    for e in range(2, 10):
        n = 1024 * (2 ** e)
        assert selection.cost_rams(n, 1024, model=m, mesh_shape=(16, 64)) \
            <= selection.cost_rams(n, 1024, model=m) * 1.001


# ---------------------------------------------------------------------------
# Mesh construction / validation.
# ---------------------------------------------------------------------------


def test_sort_mesh_nested_shapes_and_errors():
    m = sort_mesh(shape=(2, 4))
    assert dict(m.shape) == {"inter": 2, "intra": 4}
    m2 = sort_mesh(shape=(2, 2), d=2)
    assert dict(m2.shape) == {"data": 2, "inter": 2, "intra": 2}
    with pytest.raises(ValueError):
        sort_mesh(shape=(64, 64))            # more devices than exist
    with pytest.raises(ValueError):
        sort_mesh(p=16, shape=(2, 4))        # inconsistent p


def test_psort_nested_rejects_bad_args():
    x = np.arange(64, dtype=np.int32)
    with pytest.raises(ValueError):
        psort(x, config=SortConfig(p=16, mesh_shape=(2, 4),
                                   backend="sim"))        # p mismatch
    with pytest.raises(ValueError):
        psort(x, config=SortConfig(mesh_shape=(3, 4),
                                   backend="sim"))        # not a power of 2
    mesh_flat = sort_mesh(4, d=2)
    with pytest.raises(ValueError):
        psort(x, config=SortConfig(mesh_shape=(2, 4),
                                   mesh=mesh_flat))       # wrong axes
