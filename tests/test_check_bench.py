"""The perf-regression gate: per-cell ratios against the committed
baseline, with the acceptance bar that a deliberately 2×-inflated cell
fails the gate."""
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_bench import compare, main as check_main   # noqa: E402


def _bench(cells):
    """{(p, algo, e): us} → bench-JSON shaped dict."""
    bench = {}
    for (p, algo, e), us in cells.items():
        bench.setdefault(p, {}).setdefault(algo, {})[e] = us
    return {"machine": "test", "bench": bench}


BASE = {("64", "rquick", "0"): 100.0, ("64", "rams", "2"): 200.0,
        ("256", "rfis", "-3"): 50.0}


def test_identical_runs_pass():
    res = compare(_bench(BASE), _bench(BASE))
    assert not res["fail"] and not res["warn"]
    assert len(res["ok"]) == 3


def test_inflated_cell_fails_gate():
    fresh = dict(BASE)
    fresh[("64", "rams", "2")] = 400.0                 # 2x slowdown
    res = compare(_bench(BASE), _bench(fresh))
    assert [k for k, _ in res["fail"]] == [("64", "rams", "2")]


def test_warn_band_and_improvements():
    fresh = dict(BASE)
    fresh[("64", "rquick", "0")] = 130.0               # 1.3x: warn
    fresh[("256", "rfis", "-3")] = 25.0                # 2x faster
    res = compare(_bench(BASE), _bench(fresh))
    assert not res["fail"]
    assert [k for k, _ in res["warn"]] == [("64", "rquick", "0")]
    assert [k for k, _ in res["improved"]] == [("256", "rfis", "-3")]


def test_new_and_dropped_cells_do_not_fail():
    fresh = dict(BASE)
    fresh[("1024", "rams@16x64", "0")] = 999.0         # new: no baseline
    del fresh[("256", "rfis", "-3")]
    res = compare(_bench(BASE), _bench(fresh))
    assert not res["fail"]
    assert [k for k, _ in res["new"]] == [("1024", "rams@16x64", "0")]
    assert [k for k, _ in res["dropped"]] == [("256", "rfis", "-3")]


def test_dropped_cells_fail_with_flag():
    """A regression that deletes a gated cell must not silently pass:
    fail_on_dropped moves dropped baseline cells into the fail bucket."""
    fresh = dict(BASE)
    del fresh[("256", "rfis", "-3")]
    res = compare(_bench(BASE), _bench(fresh), fail_on_dropped=True)
    assert [k for k, _ in res["dropped"]] == [("256", "rfis", "-3")]
    assert [k for k, _ in res["fail"]] == [("256", "rfis", "-3")]
    # the ratio slot is None — there is no fresh measurement to ratio
    assert res["fail"][0][1] is None
    # new cells are still never failures, flag or not
    fresh[("1024", "rams@16x64", "0")] = 999.0
    res = compare(_bench(BASE), _bench(fresh), fail_on_dropped=True)
    assert [k for k, _ in res["new"]] == [("1024", "rams@16x64", "0")]
    assert [k for k, _ in res["fail"]] == [("256", "rfis", "-3")]


def test_cli_fail_on_dropped(tmp_path):
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps(_bench(BASE)))
    fresh = dict(BASE)
    del fresh[("64", "rams", "2")]
    fresh_p = tmp_path / "fresh.json"
    fresh_p.write_text(json.dumps(_bench(fresh)))
    # default stays report-only (the nightly deep lane relies on this)
    assert check_main(["--baseline", str(base_p),
                       "--fresh", str(fresh_p)]) == 0
    assert check_main(["--baseline", str(base_p), "--fresh", str(fresh_p),
                       "--fail-on-dropped"]) == 1


def test_cli_exit_codes(tmp_path):
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps(_bench(BASE)))
    ok_p = tmp_path / "ok.json"
    ok_p.write_text(json.dumps(_bench(BASE)))
    bad = dict(BASE)
    bad[("64", "rquick", "0")] = 250.0
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(_bench(bad)))
    assert check_main(["--baseline", str(base_p), "--fresh", str(ok_p)]) == 0
    assert check_main(["--baseline", str(base_p), "--fresh", str(bad_p)]) == 1


def test_cli_against_committed_baseline():
    """The committed baseline gates itself green (the CI wiring sanity)."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py"),
         "--fresh", str(REPO / "BENCH_calibrate.json")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "perf gate OK" in proc.stdout
