"""Fault-injection lane: psort under killed and straggling PEs.

The tentpole contract (ISSUE 6 / ROADMAP "elastic"): with a
``FaultPolicy``, a sim-backend ``psort`` that loses PEs mid-run — a
planned kill raising :class:`repro.core.comm.PEFailure` at trace time, or
a delayed PE flagged by the ``StepWatchdog`` straggler lane — excludes
them, re-plans the topology (``plan_sort_rescale``: survivors rounded
down to a power of two), redistributes the input and re-runs, bounded by
``run_with_restarts``.  The output must be the globally sorted **exact
multiset** of the input, and the recorded ``CommTrace`` must interleave
the injected ``fault:*`` events and ``rescale`` markers with the regular
launches.

Lanes: every test here carries ``@pytest.mark.faults``; the fast slice
(p ≤ 8, Uniform) runs in tier-1 and the fast CI job via
``-m "faults and not slow"``; the full 7-algorithm × distribution matrix
at p = 16 is ``slow`` and runs nightly.
"""
import numpy as np
import pytest

from repro.core import comm
from repro.core.api import SortConfig, psort
from repro.core.comm import FaultPlan, delay_pe, kill_pe
from repro.data.distributions import generate_instance
from repro.runtime.failures import FaultPolicy

from helpers import check_sort

pytestmark = pytest.mark.faults

ALGOS = ["gatherm", "allgatherm", "rfis", "rquick", "rams", "bitonic",
         "ssort"]
DISTS = ["Uniform", "Zero", "DeterDupl"]
# classical sample sort overflows on heavy duplicates by design (paper
# §VII-B) — rescaling cannot fix a robustness gap, so those cells are
# excluded from the fault matrix exactly as in test_sorting.py
NON_ROBUST = {("ssort", "Zero"), ("ssort", "DeterDupl")}


def _policy(*faults, **kw):
    return FaultPolicy(plan=FaultPlan(tuple(faults)), **kw)


def _assert_fault_run(info, p0, *, kills=0, delays=0, rescales=1):
    """The CommTrace/attempt evidence of an exclude-and-rescale run."""
    tr = info["comm_trace"]
    prims = [e.primitive for e in tr.injected()]
    assert prims.count("fault:kill") == kills
    assert prims.count("fault:delay") >= delays   # a delay may re-fire on retry
    marks = [e for e in tr.injected() if e.primitive == "rescale"]
    assert len(marks) == rescales
    assert all(m.group_size < p0 for m in marks)  # re-run at reduced p
    assert info["fault"]["p_final"] == marks[-1].group_size
    assert info["fault"]["restarts"] == rescales
    assert tr.launches > 0                        # regular launches interleaved
    ps = [a["p"] for a in info["fault"]["attempts"]]
    assert ps[0] == p0 and sorted(ps, reverse=True) == ps
    assert info["fault"]["attempts"][-1]["ok"]


@pytest.mark.parametrize("algorithm", ALGOS)
def test_kill_and_straggler_every_algorithm(algorithm):
    """Acceptance: 1 killed + 1 straggling PE, every algorithm — sorted
    output, exact multiset, rescaled re-runs recorded."""
    p = 8
    x = generate_instance("Uniform", p, 32 * p).astype(np.int32)
    pol = _policy(kill_pe(2), delay_pe(1, factor=8.0))
    info = check_sort(x, p, algorithm, backend="sim", fault_policy=pol)
    _assert_fault_run(info, p, kills=1, delays=1, rescales=2)
    assert info["fault"]["failed"] == (2, 1)
    assert [a["p"] for a in pol.attempts] == [8, 4, 2]


def test_single_kill_rescale_semantics():
    p = 8
    x = generate_instance("Uniform", p, 64 * p).astype(np.int32)
    pol = _policy(kill_pe(3, tag="shuffle"))
    info = check_sort(x, p, "rams", backend="sim", fault_policy=pol)
    _assert_fault_run(info, p, kills=1, rescales=1)
    kill = next(e for e in pol.trace.injected()
                if e.primitive == "fault:kill")
    assert kill.pe == 3 and kill.tag == "shuffle"
    rescale = next(e for e in pol.trace.injected()
                   if e.primitive == "rescale")
    assert rescale.pe == 3 and rescale.group_size == 4   # 7 survivors → 4


def test_straggler_only_excluded_via_watchdog():
    p = 8
    x = generate_instance("Uniform", p, 32 * p).astype(np.int32)
    pol = _policy(delay_pe(5, factor=16.0))
    info = check_sort(x, p, "rquick", backend="sim", fault_policy=pol)
    _assert_fault_run(info, p, delays=1, rescales=1)
    rescale = next(e for e in pol.trace.injected()
                   if e.primitive == "rescale")
    assert rescale.pe == 5 and rescale.tag == "straggler"


def test_mild_delay_below_threshold_is_not_a_straggler():
    """A delay under the k_mad/1.5× gates completes in one attempt."""
    p = 8
    x = generate_instance("Uniform", p, 16 * p).astype(np.int32)
    pol = _policy(delay_pe(2, factor=1.2))
    check_sort(x, p, "rquick", backend="sim", fault_policy=pol)
    assert len(pol.attempts) == 1 and pol.attempts[0]["ok"]
    assert not [e for e in pol.trace.injected()
                if e.primitive == "rescale"]


def test_two_kills_two_rescales():
    p = 8
    x = generate_instance("Uniform", p, 32 * p).astype(np.int32)
    pol = _policy(kill_pe(6), kill_pe(1, after=2))
    info = check_sort(x, p, "rfis", backend="sim", fault_policy=pol)
    _assert_fault_run(info, p, kills=2, rescales=2)
    assert [a["p"] for a in pol.attempts] == [8, 4, 2]


def test_nested_mesh_kill_preserves_inner_axis():
    x = generate_instance("Uniform", 8, 64 * 8).astype(np.int32)
    pol = _policy(kill_pe(5))
    out, info = psort(x, config=SortConfig(mesh_shape=(2, 4),
                                           algorithm="rams", backend="sim",
                                           fault_policy=pol),
                      return_info=True)
    assert (np.asarray(out) == np.sort(x)).all()
    assert [a["mesh_shape"] for a in pol.attempts] == [(2, 4), (1, 4)]
    assert info["mesh_shape"] == (1, 4)


def test_batched_rows_survive_fault():
    """2-D keys: every row of the batch re-sorts on the rescaled mesh."""
    p = 4
    r = np.random.default_rng(3)
    xs = r.integers(0, 1 << 20, size=(3, 16 * p)).astype(np.int32)
    pol = _policy(kill_pe(1))
    out, info = psort(xs, config=SortConfig(p=p, algorithm="rquick",
                                            backend="sim",
                                            fault_policy=pol),
                      return_info=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(xs, axis=-1))
    assert info["fault"]["p_final"] == 2


def test_auto_reconsults_selection_at_reduced_p():
    p = 8
    x = generate_instance("Uniform", p, 64 * p).astype(np.int32)
    pol = _policy(kill_pe(0))
    info = check_sort(x, p, "auto", backend="sim", fault_policy=pol)
    algos = [a["algorithm"] for a in pol.attempts]
    assert all(a in ALGOS + ["ntb-quick", "ntb-ams"] for a in algos)
    assert info["algorithm"] == algos[-1]


def test_restart_budget_exhausted_reraises():
    p = 4
    x = np.arange(64, dtype=np.int32)
    pol = _policy(kill_pe(0), kill_pe(1), max_restarts=1)
    with pytest.raises(comm.PEFailure):
        psort(x, config=SortConfig(p=p, algorithm="rquick",
                                   backend="sim", fault_policy=pol))


def test_fault_policy_requires_sim_backend():
    pol = _policy(kill_pe(0))
    with pytest.raises(ValueError, match="sim"):
        psort(np.arange(8, dtype=np.int32),
              config=SortConfig(p=2, algorithm="rquick",
                                backend="shard_map", fault_policy=pol))


def test_injected_events_excluded_from_launch_stats():
    """fault:*/rescale pseudo-events must not pollute the cost-model
    aggregates (launches / wire bytes) the calibrator fits against."""
    p = 4
    x = np.arange(128, dtype=np.int32)
    pol = _policy(kill_pe(2))
    psort(x, config=SortConfig(p=p, algorithm="rquick", backend="sim",
                               fault_policy=pol))
    tr = pol.trace
    assert len(tr.injected()) == 2                  # kill + rescale
    assert tr.launches == len(tr.events) - 2
    assert all(e.primitive in tr.PRIMITIVES or e.bytes == 0
               for e in tr.events)


def test_sort_mesh_exclude_rederives_reduced_mesh():
    """Device-mesh side of the rescale path: failed device positions are
    excluded and the survivors renumber into the reduced mesh."""
    import jax
    from repro.dist.sharding import sort_mesh
    devs = jax.devices()                       # 8 emulated CPU devices
    m = sort_mesh(p=4, devices=devs[:5], exclude=(2,))
    assert dict(m.shape) == {"data": 1, "sort": 4}
    assert devs[2] not in list(m.devices.ravel())
    m2 = sort_mesh(shape=(2, 2), devices=devs[:6], exclude=(1, 3))
    assert dict(m2.shape) == {"inter": 2, "intra": 2}
    assert not {devs[1], devs[3]} & set(m2.devices.ravel())
    with pytest.raises(ValueError, match="exclude"):
        sort_mesh(p=2, devices=devs[:2], exclude=(7,))


def test_empty_plan_single_attempt():
    x = np.arange(64, dtype=np.int32)
    pol = FaultPolicy()
    info = check_sort(x, 4, "bitonic", backend="sim", fault_policy=pol)
    assert len(pol.attempts) == 1
    assert info["fault"]["p_final"] == 4 and not info["fault"]["failed"]


def test_external_kill_during_merge_pass():
    """ISSUE 8 satellite: a kill during the external k-way merge pass
    (tag ``ext:merge``) must exclude-and-rescale with the runs
    redistributed — ``plan_sort_rescale`` composes with the multi-pass
    external state because every attempt rebuilds runs/splitters/slices
    from the host-resident input at the reduced topology."""
    from repro.core import ExternalPolicy
    p = 8
    x = generate_instance("Uniform", p, 32 * p).astype(np.int32)
    pol = _policy(kill_pe(3, tag="ext:merge"))
    info = check_sort(x, p, "auto", backend="sim", fault_policy=pol,
                      external=ExternalPolicy(budget=8))
    _assert_fault_run(info, p, kills=1, rescales=1)
    assert info["algorithm"] == "external"
    kill = next(e for e in pol.trace.injected()
                if e.primitive == "fault:kill")
    assert kill.pe == 3 and kill.tag == "ext:merge"
    # both attempts ran the external lane: n/p exceeds the budget before
    # and (a fortiori) after the rescale to p = 4
    assert [a["algorithm"] for a in pol.attempts] == ["external"] * 2
    assert [a["p"] for a in pol.attempts] == [8, 4]


def test_external_kill_during_exchange_pass():
    """A mid-stream kill (second run's all_to_all) re-runs cleanly: no
    partial pass state leaks into the rescaled attempt."""
    from repro.core import ExternalPolicy
    p = 4
    x = generate_instance("Staggered", p, 32 * p).astype(np.int32)
    pol = _policy(kill_pe(1, tag="ext:pass1"))
    info = check_sort(x, p, "auto", backend="sim", fault_policy=pol,
                      external=ExternalPolicy(budget=8))
    _assert_fault_run(info, p, kills=1, rescales=1)
    kill = next(e for e in pol.trace.injected()
                if e.primitive == "fault:kill")
    assert kill.tag == "ext:pass1"


def test_rescale_crosses_into_external_regime():
    """Shrinking p grows n/p: an in-core attempt whose rescale pushes the
    shard past the budget must restart on the external lane."""
    from repro.core import ExternalPolicy
    p = 8
    x = generate_instance("Uniform", p, 16 * p).astype(np.int32)
    pol = _policy(kill_pe(2))
    info = check_sort(x, p, "auto", backend="sim", fault_policy=pol,
                      external=ExternalPolicy(budget=24))
    # per = 16 <= 24 in-core at p=8; per = 32 > 24 external at p=4
    algos = [a["algorithm"] for a in pol.attempts]
    assert algos[0] != "external" and algos[-1] == "external"
    assert info["fault"]["p_final"] == 4


@pytest.mark.slow
@pytest.mark.parametrize("instance", DISTS)
@pytest.mark.parametrize("algorithm", ALGOS)
def test_fault_matrix_full(algorithm, instance):
    """Nightly: 2 kills + 1 straggler at p = 16, all algorithms × the
    robustness distributions — sorted exact multiset at p_final = 2."""
    if (algorithm, instance) in NON_ROBUST:
        pytest.skip("classical sample sort is non-robust on heavy "
                    "duplicates by design (paper §VII-B)")
    p = 16
    x = generate_instance(instance, p, 64 * p).astype(np.int32)
    pol = _policy(kill_pe(3), kill_pe(5, after=2), delay_pe(1, factor=8.0))
    info = check_sort(x, p, algorithm, backend="sim", fault_policy=pol)
    _assert_fault_run(info, p, kills=2, delays=1, rescales=3)
    assert [a["p"] for a in pol.attempts] == [16, 8, 4, 2]


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["rams", "rquick", "bitonic"])
def test_fault_matrix_nested(algorithm):
    """Nightly: kill + straggler on a hierarchical (4, 4) mesh."""
    x = generate_instance("DeterDupl", 16, 64 * 16).astype(np.int32)
    pol = _policy(kill_pe(9), delay_pe(2, factor=8.0))
    out, info = psort(x, config=SortConfig(mesh_shape=(4, 4),
                                           algorithm=algorithm,
                                           backend="sim", fault_policy=pol),
                      return_info=True)
    assert (np.asarray(out) == np.sort(x)).all()
    _assert_fault_run(info, 16, kills=1, delays=1, rescales=2)
    assert [a["mesh_shape"] for a in pol.attempts] == [(4, 4), (2, 4), (1, 4)]
