"""Collectives-runtime contract tests.

1. Grouped-collective edge cases: ``SimCollectives`` (both the one-shot
   gather path and the forced-ring chunked path) must match
   ``LaxCollectives`` under shard_map at p = 8 — including single-member
   groups, non-contiguous groups and ``tiled=True`` all_gather.
2. ``CountingCollectives``: forwards results unchanged and records the
   per-primitive launch counts / payload bytes / group sizes that
   ``benchmarks/calibrate.py`` fits the machine profile against.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import comm
from repro.core.api import SortConfig, _sort_body, trace_collectives
from repro.runtime.compat import shard_map

PP = 8
CONTIG = [[0, 1, 2, 3], [4, 5, 6, 7]]
STRIDED = [[0, 2, 4, 6], [1, 3, 5, 7]]          # non-contiguous
SINGLES = [[i] for i in range(PP)]              # single-member groups
FULL = [list(range(PP))]                        # one group == the axis
GROUPS = {"contig": CONTIG, "strided": STRIDED, "singles": SINGLES,
          "full": FULL}


def _run_sim(fn, x, chunk_bytes=None):
    impl = comm.SimCollectives(chunk_bytes=chunk_bytes) \
        if chunk_bytes is not None else None
    return jax.jit(comm.sim_map(fn, "pe", PP, impl=impl))(x)


def _run_shard_map(fn, x):
    mesh = Mesh(np.array(jax.devices()[:PP]), ("pe",))

    def blk(v):
        out = fn(v[0])
        return jax.tree.map(lambda a: a[None], out)

    with mesh:
        return jax.jit(shard_map(blk, mesh=mesh, in_specs=(P("pe"),),
                                 out_specs=P("pe")))(x)


def _check_all_backends(fn, x):
    """lax reference vs sim one-shot vs sim forced-ring (chunk_bytes=0)."""
    ref = np.asarray(_run_shard_map(fn, x))
    one_shot = np.asarray(_run_sim(fn, x))
    ring = np.asarray(_run_sim(fn, x, chunk_bytes=0))
    np.testing.assert_array_equal(ref, one_shot)
    np.testing.assert_array_equal(ref, ring)


@pytest.mark.parametrize("gname", sorted(GROUPS))
@pytest.mark.parametrize("tiled", [False, True])
def test_grouped_all_gather_matches_lax(gname, tiled):
    groups = GROUPS[gname]
    x = jnp.arange(PP * 3, dtype=jnp.int32).reshape(PP, 3)

    def fn(v):
        return comm.all_gather(v, "pe", axis_index_groups=groups, tiled=tiled)

    _check_all_backends(fn, x)


@pytest.mark.parametrize("gname", sorted(GROUPS))
def test_grouped_psum_matches_lax(gname):
    groups = GROUPS[gname]
    x = (jnp.arange(PP * 4, dtype=jnp.int32).reshape(PP, 4) * 7 + 3)

    def fn(v):
        return comm.psum(v, "pe", axis_index_groups=groups)

    _check_all_backends(fn, x)


@pytest.mark.parametrize("gname", sorted(GROUPS))
def test_grouped_all_to_all_matches_lax(gname):
    groups = GROUPS[gname]
    gsize = len(groups[0])
    blk = 2
    x = jnp.arange(PP * gsize * blk, dtype=jnp.int32).reshape(PP, gsize * blk)

    def fn(v):
        return comm.all_to_all(v, "pe", split_axis=0, concat_axis=0,
                               axis_index_groups=groups, tiled=True)

    _check_all_backends(fn, x)


def test_ungrouped_all_gather_tiled_matches_lax():
    x = jnp.arange(PP * 2, dtype=jnp.int32).reshape(PP, 2)

    def fn(v):
        return comm.all_gather(v, "pe", tiled=True)

    _check_all_backends(fn, x)


def test_rams_forced_ring_bitwise_equal():
    """A full two-level RAMS sort under the forced-ring chunked collectives
    must be bit-identical to the one-shot sim path."""
    p, per = 8, 16
    body = _sort_body("sort", p, "rams", 2 * per, 2 * per,
                      (("levels", 2),))
    r = np.random.default_rng(0)
    keys2d = jnp.asarray(r.integers(0, 2**32, size=(p, per), dtype=np.uint64)
                         .astype(np.uint32))
    counts = jnp.full((p,), per, jnp.int32)
    default = jax.jit(comm.sim_map(body, "sort", p))(keys2d, counts)
    forced = jax.jit(comm.sim_map(
        body, "sort", p,
        impl=comm.SimCollectives(chunk_bytes=0)))(keys2d, counts)
    for a, b in zip(jax.tree.leaves(default), jax.tree.leaves(forced)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# CountingCollectives
# ---------------------------------------------------------------------------


def test_counting_records_and_forwards():
    x = jnp.arange(PP * 4, dtype=jnp.int32).reshape(PP, 4)
    perm = [(i, (i + 1) % PP) for i in range(PP)]

    def fn(v):
        a = comm.ppermute(v, "pe", perm)
        b = comm.ppermute(a, "pe", perm)
        g = comm.all_gather(v, "pe", axis_index_groups=CONTIG, tiled=True)
        s = comm.psum(v[0], "pe")
        return b + jnp.sum(g).astype(v.dtype) + s

    counter = comm.CountingCollectives(comm.SIM)
    out = jax.jit(comm.sim_map(fn, "pe", PP, impl=counter))(x)
    plain = jax.jit(comm.sim_map(fn, "pe", PP))(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))

    tr = counter.trace
    assert tr.counts() == {"ppermute": 2, "all_gather": 1, "psum": 1}
    assert tr.p2p_launches == 2 and tr.fused_launches == 2
    # payload bytes are per-PE and static: 4 int32 per ppermute, 4 for the
    # grouped gather input, 1 scalar for the psum
    assert tr.payload_bytes() == {"ppermute": 32, "all_gather": 16, "psum": 4}
    assert tr.wire_bytes() == 52
    # group sizes: the gather was grouped (4), the psum full-axis (None)
    gathers = [e for e in tr.events if e.primitive == "all_gather"]
    assert gathers[0].group_size == 4
    psums = [e for e in tr.events if e.primitive == "psum"]
    assert psums[0].group_size is None
    assert tr.fused_hops(PP) == pytest.approx(4 ** (1 / 3) + 8 ** (1 / 3))


def test_counting_context_manager_wraps_current():
    with comm.counting() as tr:
        # tracing only — eval_shape never executes FLOPs
        def fn(v):
            return comm.ppermute(v, "pe", [(i, i) for i in range(PP)])
        jax.eval_shape(comm.sim_map(fn, "pe", PP, impl=comm.current()),
                       jax.ShapeDtypeStruct((PP, 2), jnp.float32))
    assert tr.counts() == {"ppermute": 1}
    assert tr.payload_bytes()["ppermute"] == 8


def test_counting_scope_survives_sim_map():
    """The ROADMAP workflow `with comm.counting(): psort(backend='sim')`
    must record the simulated collectives — sim_map re-wraps its backend
    with the ambient counting trace instead of discarding the scope."""
    from repro.core.api import psort
    x = np.random.default_rng(9).integers(0, 1000, 97).astype(np.int32)
    with comm.counting() as tr:
        out = psort(x, config=SortConfig(p=PP, algorithm="rquick",
                                         backend="sim"))
    assert (np.asarray(out) == np.sort(x)).all()
    assert tr.launches > 0 and tr.counts()["ppermute"] > 0


def test_trace_collectives_shapes_of_table1():
    """The counted traces reproduce Table I's structure: hypercube
    algorithms are all point-to-point; RAMS launches fused collectives."""
    t_rquick = trace_collectives(64 * PP, SortConfig(p=PP, algorithm="rquick"))
    assert t_rquick.p2p_launches > 0 and t_rquick.fused_launches == 0
    t_rams = trace_collectives(64 * PP, SortConfig(p=PP, algorithm="rams"))
    assert t_rams.fused_launches > 0
    assert t_rams.wire_bytes() > 0
    # gatherm: d = log2 p exchange steps of the binomial tree
    t_g = trace_collectives(PP // 2, SortConfig(p=PP, algorithm="gatherm"))
    assert t_g.counts()["ppermute"] >= 3
