"""Multi-axis mesh contract: sorting within named subgroups of a 2-D mesh.

The acceptance bar of the multi-axis PR: batched ``psort`` over the sort
axis of a (d, p) mesh must be **bitwise identical** to d independent
single-axis runs, for every algorithm, on both backends — shard_map over a
real 2-D device mesh (d×p = 2×4 on the 8 emulated CPU devices) and the sim
backend's ``sim_map(mesh=(d, p))`` mode (d×p = 4×64 emulated PEs).  Plus
the grouped-collective edge cases *inside* mesh mode (single-member
subgroups, subgroups spanning non-adjacent mesh positions, the counting
decorator, the forced-ring chunked path), each cross-checked against
per-row single-axis evaluation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import comm
from repro.core.api import SortConfig, psort, trace_collectives
from repro.data.distributions import generate_instance
from repro.dist.sharding import sort_mesh

ALL_ALGOS = ["rquick", "rfis", "rams", "bitonic", "ssort", "gatherm",
             "allgatherm"]


def _rows(d, p, n_per, seed=3):
    """d independent instances with different content per row."""
    return np.stack([generate_instance("Uniform", p, n_per, seed=seed + r)
                     .astype(np.int32) for r in range(d)])


def _assert_rows_match_1d(xs, p, algorithm, backend):
    """Batched run row r ≡ 1-D run of row r (keys, perm, counts, overflow)."""
    cfg = SortConfig(p=p, algorithm=algorithm, backend=backend)
    out2, info2 = psort(xs, config=cfg, return_info=True)
    out2 = np.asarray(out2)
    assert info2["overflow"] == 0
    for r in range(xs.shape[0]):
        out1, info1 = psort(xs[r], config=cfg, return_info=True)
        assert (out2[r] == np.asarray(out1)).all(), (algorithm, backend, r)
        assert (info2["perm"][r] == info1["perm"]).all(), (algorithm, r)
        assert (info2["counts"][r] == info1["counts"]).all(), (algorithm, r)
        assert (out2[r] == np.sort(xs[r])).all(), (algorithm, r)


# ---------------------------------------------------------------------------
# Acceptance: all seven algorithms, both backends.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_shard_map_2x4_bitwise_vs_single_axis(algorithm):
    d, p = 2, 4
    xs = _rows(d, p, 37 * p)
    _assert_rows_match_1d(xs, p, algorithm, "shard_map")


@pytest.mark.parametrize("algorithm", ["rquick", "rams"])
def test_sim_4x64_bitwise_vs_single_axis(algorithm):
    d, p = 4, 64
    xs = _rows(d, p, 24 * p)
    _assert_rows_match_1d(xs, p, algorithm, "sim")


@pytest.mark.slow
@pytest.mark.parametrize("algorithm",
                         [a for a in ALL_ALGOS if a not in ("rquick", "rams")])
def test_sim_4x64_bitwise_vs_single_axis_full(algorithm):
    d, p = 4, 64
    xs = _rows(d, p, 24 * p)
    _assert_rows_match_1d(xs, p, algorithm, "sim")


def test_shard_map_explicit_mesh_and_defaults():
    """An explicit sort_mesh and the implicit default agree bitwise."""
    d, p = 2, 4
    xs = _rows(d, p, 11 * p)
    mesh = sort_mesh(p, d=d)
    out_explicit = np.asarray(psort(
        xs, config=SortConfig(mesh=mesh, algorithm="rquick")))
    out_default = np.asarray(psort(xs, config=SortConfig(algorithm="rquick")))
    assert (out_explicit == out_default).all()
    assert (out_explicit == np.sort(xs, axis=-1)).all()


# ---------------------------------------------------------------------------
# Grouped collectives inside sim_map(mesh=...): the edge cases of
# tests/test_comm.py replayed within a (d, p) mesh and cross-checked
# against per-row single-axis evaluation.
# ---------------------------------------------------------------------------

D, P = 3, 8
STRIDED = [[0, 2, 4, 6], [1, 3, 5, 7]]         # non-adjacent mesh positions
SINGLES = [[i] for i in range(P)]              # single-member subgroups
CONTIG = [[0, 1, 2, 3], [4, 5, 6, 7]]


def _grouped_body(groups):
    def fn(v):
        g = comm.all_gather(v, "sort", axis_index_groups=groups, tiled=True)
        s = comm.psum(v, "sort", axis_index_groups=groups)
        a = comm.all_to_all(jnp.tile(v, (len(groups[0]),)), "sort",
                            split_axis=0, concat_axis=0,
                            axis_index_groups=groups, tiled=True)
        return g, s, a
    return fn


def _mesh_vs_rows(fn, x, chunk_bytes=None):
    """sim_map(mesh=(D, P)) ≡ per-row sim_map(p=P), leaf-by-leaf bitwise."""
    impl = comm.SimCollectives(chunk_bytes=chunk_bytes) \
        if chunk_bytes is not None else None
    out = jax.jit(comm.sim_map(fn, "sort", P, impl=impl, mesh=(D, P),
                               data_axis="data"))(x)
    for r in range(D):
        ref = jax.jit(comm.sim_map(fn, "sort", P, impl=impl))(x[r])
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a)[r], np.asarray(b))


@pytest.mark.parametrize("gname,groups", [("strided", STRIDED),
                                          ("singles", SINGLES),
                                          ("contig", CONTIG)])
def test_grouped_collectives_inside_mesh(gname, groups):
    x = jnp.arange(D * P * 4, dtype=jnp.int32).reshape(D, P, 4) * 3 + 1
    _mesh_vs_rows(_grouped_body(groups), x)


@pytest.mark.parametrize("gname,groups", [("strided", STRIDED),
                                          ("contig", CONTIG)])
def test_grouped_collectives_inside_mesh_forced_ring(gname, groups):
    """The chunked ring evaluation (chunk_bytes=0) under the mesh mode."""
    x = jnp.arange(D * P * 4, dtype=jnp.int32).reshape(D, P, 4) * 3 + 1
    _mesh_vs_rows(_grouped_body(groups), x, chunk_bytes=0)


def test_counting_inside_mesh_mode():
    """CountingCollectives under sim_map(mesh=...): the per-PE trace is
    identical to the d = 1 trace — the data axis adds no communication."""
    def fn(v):
        g = comm.all_gather(v, "sort", axis_index_groups=CONTIG, tiled=True)
        return g.sum() + comm.psum(v, "sort")

    traces = []
    for mesh, data_axis in ((None, None), ((D, P), "data")):
        counter = comm.CountingCollectives(comm.SIM)
        lead = (P,) if mesh is None else (D, P)
        jax.eval_shape(comm.sim_map(fn, "sort", P, impl=counter, mesh=mesh,
                                    data_axis=data_axis),
                       jax.ShapeDtypeStruct(lead + (4,), jnp.int32))
        traces.append(counter.trace)
    assert traces[0].summary() == traces[1].summary()
    assert traces[1].counts() == {"all_gather": 1, "psum": 1}


def test_trace_collectives_d_invariance():
    """The EXPERIMENTS.md subgroup-grid property, at API level."""
    t1 = trace_collectives(32 * 16, SortConfig(p=16, algorithm="rams"))
    t4 = trace_collectives(32 * 16, SortConfig(p=16, algorithm="rams"),
                           d=4)
    assert t1.summary() == t4.summary()


# ---------------------------------------------------------------------------
# Input validation and helpers.
# ---------------------------------------------------------------------------


def test_sort_mesh_shapes_and_errors():
    m = sort_mesh(4, d=2)
    assert dict(m.shape) == {"data": 2, "sort": 4}
    m1 = sort_mesh(d=2)                     # p defaults to ndev // d
    assert m1.shape["data"] == 2
    with pytest.raises(ValueError):
        sort_mesh(1024, d=2)                # more devices than exist
    with pytest.raises(ValueError):
        sort_mesh(4, d=0)


def test_batched_psort_rejects_bad_args():
    xs = np.arange(32, dtype=np.int32).reshape(2, 16)
    with pytest.raises(ValueError):
        psort(xs, config=SortConfig(algorithm="rquick",
                                    backend="sim"))       # p required
    with pytest.raises(ValueError):
        psort(xs[None], config=SortConfig(p=4, algorithm="rquick",
                                          backend="sim"))  # 3-D keys
    from jax.sharding import Mesh
    mesh1d = Mesh(np.array(jax.devices()[:4]), ("sort",))
    with pytest.raises(ValueError):
        psort(xs, config=SortConfig(mesh=mesh1d,
                                    algorithm="rquick"))  # no data axis
    mesh_wrong_d = sort_mesh(2, d=4)
    with pytest.raises(ValueError):
        psort(xs, config=SortConfig(mesh=mesh_wrong_d,
                                    algorithm="rquick"))  # d mismatch
