"""Unit tests for the paper's building blocks (§II, §III): hypercube ops,
randomized shuffling, median windows, data distributions, HLO cost parser."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import types as ct
from repro.core import hypercube as hc

from repro.runtime.compat import shard_map

PDEV = 8


def _mesh(p=PDEV):
    return Mesh(np.array(jax.devices()[:p]), ("sort",))


def _run(body, *arrays, p=PDEV, out_specs=None):
    mesh = _mesh(p)
    nspec = tuple(P("sort") for _ in arrays)
    with mesh:
        return jax.jit(shard_map(body, mesh=mesh, in_specs=nspec,
                                 out_specs=out_specs or P("sort")))(*arrays)


def test_hc_exchange_is_involution():
    x = np.arange(PDEV, dtype=np.int32).reshape(PDEV, 1)

    def body(blk):
        v = blk[0]
        w = hc.hc_exchange(v, "sort", PDEV, 1)
        return w[None]

    out = np.asarray(_run(body, x)).ravel()
    assert (out == np.arange(PDEV) ^ 2).all()


def test_butterfly_sum_matches_psum():
    x = np.random.default_rng(0).normal(size=(PDEV, 4)).astype(np.float32)

    def body(blk):
        return hc.butterfly_sum(blk[0], "sort", PDEV,
                                range(3))[None]

    out = np.asarray(_run(body, x))
    np.testing.assert_allclose(out, np.broadcast_to(x.sum(0), (PDEV, 4)),
                               rtol=1e-5)


def test_subcube_prefix_sum():
    x = np.arange(PDEV, dtype=np.int64).reshape(PDEV, 1) + 1

    def body(blk):
        pre, tot = hc.subcube_prefix_sum(blk[0, 0], "sort", PDEV, range(3))
        return jnp.stack([pre, tot])[None]

    out = np.asarray(_run(body, x))
    expect_pre = np.cumsum(np.arange(PDEV) + 1) - (np.arange(PDEV) + 1)
    assert (out[:, 0] == expect_pre).all()
    assert (out[:, 1] == (PDEV * (PDEV + 1)) // 2).all()


def test_hypercube_shuffle_preserves_multiset():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 1000, size=(PDEV, 16)).astype(np.uint32)

    def body(blk):
        sh = ct.make_shard(blk[0], capacity=64, sort_local=False)
        out, ovf = hc.hypercube_shuffle(sh, "sort", PDEV, seed=7)
        return out.keys[None], out.count[None], ovf[None]

    ks, cnt, ovf = _run(body, keys, out_specs=(P("sort"),) * 3)
    ks, cnt = np.asarray(ks), np.asarray(cnt)
    assert int(np.asarray(ovf).sum()) == 0
    got = np.sort(np.concatenate([ks[i, :cnt[i]] for i in range(PDEV)]))
    assert (got == np.sort(keys.ravel())).all()
    # shuffle must actually move data between PEs (w.h.p.)
    assert any(cnt[i] != 16 for i in range(PDEV)) or \
        not all((np.sort(ks[i, :cnt[i]]) == np.sort(keys[i])).all()
                for i in range(PDEV))


def test_alltoall_shuffle_preserves_multiset():
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 1000, size=(PDEV, 32)).astype(np.uint32)

    def body(blk):
        sh = ct.make_shard(blk[0], capacity=32, sort_local=False)
        out, ovf = hc.alltoall_shuffle(sh, "sort", PDEV, seed=3,
                                       slot_cap=16)
        out, o2 = ct.resize(out, 96)
        return out.keys[None], out.count[None], (ovf + o2)[None]

    ks, cnt, ovf = _run(body, keys, out_specs=(P("sort"),) * 3)
    assert int(np.asarray(ovf).sum()) == 0
    ks, cnt = np.asarray(ks), np.asarray(cnt)
    got = np.sort(np.concatenate([ks[i, :cnt[i]] for i in range(PDEV)]))
    assert (got == np.sort(keys.ravel())).all()


def test_distributions_shapes_and_ranges():
    from repro.data.distributions import INSTANCES, generate_instance
    for name in INSTANCES:
        x = generate_instance(name, 8, 128)
        assert x.shape == (128,)
        assert x.min() >= 0 and x.max() < 2 ** 32, name
    assert len(np.unique(generate_instance("DeterDupl", 8, 512))) <= 3
    assert (generate_instance("Zero", 8, 100) == 0).all()


def test_hlo_cost_parser_on_synthetic_module():
    from repro.launch import hlo_cost
    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ar = f32[8,8] all-reduce(%a), replica_groups={}, to_apply=%cond
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    r = hlo_cost.analyze(hlo)
    # dot: 2*64*8 = 1024 flops × 10 trips
    assert r["flops"] >= 10 * 1024
    assert r["flops"] < 10 * 1024 + 500
    assert r["collective_bytes"]["all-reduce"] == 2 * 256
    assert r["unknown_trip_counts"] == 0


def test_selection_regime_structure():
    """The paper's headline: regimes ordered GatherM→RFIS→RQuick→RAMS."""
    from repro.core.selection import regime_table
    rows = regime_table(262144)
    order = []
    for _, _, a in rows:
        if not order or order[-1] != a:
            order.append(a)
    assert order == ["gatherm", "rfis", "rquick", "rams"], order


def test_length_balanced_batching_reduces_waste():
    from repro.data.pipeline import length_balanced_batches
    rng = np.random.default_rng(3)
    lengths = np.minimum(32 + (rng.zipf(1.5, size=1024) % 992), 1024)
    _, before, after = length_balanced_batches(lengths, batch=16, p=4)
    assert after < before
