"""MoE sort-based dispatch: equivalence with the dense baseline and
robustness under router skew (the paper's DeterDupl regime in the model)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config, smoke_variant
from repro.models import moe as M


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_local_matches_dense(setup):
    cfg, p, x = setup
    y_dense, _ = M.moe_dense(x, p, cfg)
    y_local, _ = M.moe_local(x, p, cfg, capacity_factor=8.0)  # no drops
    np.testing.assert_allclose(np.asarray(y_dense, np.float32),
                               np.asarray(y_local, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_ep_shardmap_matches_dense(setup):
    cfg, p, x = setup
    devs = jax.devices()[:4]
    mesh = Mesh(np.array(devs).reshape(2, 2), ("data", "model"))
    y_dense, _ = M.moe_dense(x, p, cfg)
    with mesh:
        y_ep, _ = jax.jit(lambda xx, pp: M.moe_ep_shardmap(
            xx, pp, cfg, mesh, data_axes=("data",), capacity_factor=16.0,
            slot_factor=16.0))(x, p)
    np.testing.assert_allclose(np.asarray(y_dense, np.float32),
                               np.asarray(y_ep, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_ep_dispatch_skewed_router(setup):
    """All tokens to one expert (the AllToOne analogue): capacity bounds
    hold, no NaNs, overflow manifests as dropped items not corruption."""
    cfg, p, x = setup
    p_skew = dict(p)
    router = np.zeros((cfg.d_model, cfg.n_experts), np.float32)
    router[:, 0] = 10.0                      # everything routes to expert 0
    p_skew["router"] = jnp.asarray(router)
    devs = jax.devices()[:4]
    mesh = Mesh(np.array(devs).reshape(2, 2), ("data", "model"))
    with mesh:
        y, aux = jax.jit(lambda xx, pp: M.moe_ep_shardmap(
            xx, pp, cfg, mesh, data_axes=("data",)))(x, p_skew)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_ep_sim_matches_shardmap_and_dense(setup):
    """The same EP dispatch body on an emulated (d, ep) sim mesh: bitwise
    equal to the shard_map run at the same layout, allclose to dense, and
    runnable at d·ep beyond the physical device count."""
    cfg, p, x = setup
    devs = jax.devices()[:4]
    mesh = Mesh(np.array(devs).reshape(2, 2), ("data", "model"))
    with mesh:
        y_ep, _ = jax.jit(lambda xx, pp: M.moe_ep_shardmap(
            xx, pp, cfg, mesh, data_axes=("data",), capacity_factor=16.0,
            slot_factor=16.0))(x, p)
    y_sim, _ = jax.jit(lambda xx, pp: M.moe_ep_sim(
        xx, pp, cfg, d=2, ep=2, capacity_factor=16.0,
        slot_factor=16.0))(x, p)
    np.testing.assert_array_equal(np.asarray(y_sim), np.asarray(y_ep))

    # d·ep = 2·4 = 8 emulated PEs with ep = E (every expert its own PE)
    y8, _ = jax.jit(lambda xx, pp: M.moe_ep_sim(
        xx, pp, cfg, d=2, ep=cfg.n_experts, capacity_factor=16.0,
        slot_factor=16.0))(x, p)
    y_dense, _ = M.moe_dense(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y8, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_ep_sim_rejects_indivisible_layout(setup):
    cfg, p, x = setup
    with pytest.raises(ValueError):
        M.moe_ep_sim(x, p, cfg, d=3, ep=2)       # B=2 not divisible by 3


def test_group_by_expert_capacity():
    eids = jnp.asarray(np.array([0, 0, 0, 1, 0, 2, 0], np.int32))
    slot, kept = M._group_by_expert(eids, 4, capacity=2)
    assert list(np.asarray(slot)[:3]) == [0, 1, 2]
    assert list(np.asarray(kept)) == [True, True, False, True, False, True,
                                      False]
