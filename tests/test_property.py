"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional test dependency (``pip install -e .[test]``);
the module is skipped wholesale when it is absent.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import types as ct
from helpers import check_sort

ALGOS = ["rquick", "rfis", "rams", "bitonic"]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=0, max_size=300),
       st.sampled_from(ALGOS))
def test_psort_matches_npsort(xs, algorithm):
    check_sort(np.array(xs, np.int32), 4, algorithm)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.sampled_from([0, 1, -1, 2**31 - 1, -2**31]),
                min_size=1, max_size=200),
       st.sampled_from(ALGOS))
def test_psort_extreme_duplicates(xs, algorithm):
    check_sort(np.array(xs, np.int32), 4, algorithm)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(allow_nan=False, width=32), min_size=0,
                max_size=200))
def test_key_transform_order_isomorphism(xs):
    import jax.numpy as jnp
    x = np.array(xs, np.float32)
    u = np.asarray(ct.key_to_uint(jnp.asarray(x)))
    # order-preserving
    order_x = np.argsort(x, kind="stable")
    assert (np.sort(x) == x[np.argsort(u, kind="stable")]).all() or \
        (np.sort(u) == u[order_x]).all()
    # invertible
    back = np.asarray(ct.uint_to_key(jnp.asarray(u), jnp.float32))
    assert (back == x).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 400), st.integers(0, 10**9))
def test_merge_shards_preserves_multiset(n, seed):
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    a = np.sort(r.integers(0, 50, size=n)).astype(np.uint32)
    b = np.sort(r.integers(0, 50, size=n // 2 + 1)).astype(np.uint32)
    sa = ct.make_shard(jnp.asarray(a), capacity=n + 8)
    sb = ct.make_shard(jnp.asarray(b), capacity=n + 8)
    merged, ovf = ct.merge_shards(sa, sb, capacity=2 * n + 16)
    assert int(ovf) == 0
    got = np.asarray(merged.keys)[:int(merged.count)]
    assert (got == np.sort(np.concatenate([a, b]))).all()


@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=1e-6, max_value=1e6, allow_nan=False,
                 allow_infinity=False),
       st.integers(1, 250), st.integers(1, 20), st.floats(1.0, 20.0))
def test_watchdog_never_flags_constant_stream(dt, n, warmup, k_mad):
    """A perfectly steady step-time stream must never look like a
    straggler, for any stream length / warmup / threshold."""
    from repro.runtime.failures import StepWatchdog
    wd = StepWatchdog(k_mad=k_mad, warmup=warmup)
    for i in range(n):
        assert not wd.observe(i, dt)
    assert wd.flagged == []


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 200), st.integers(0, 10**9))
def test_median_estimator_quality(n, seed):
    """Single-PE window: splitter must be the true median (±1 rank)."""
    import jax
    import jax.numpy as jnp
    from repro.core.median import local_window, splitter_from_window, unlift
    r = np.random.default_rng(seed)
    x = np.sort(r.integers(0, 2**31, size=n)).astype(np.uint32)
    sh = ct.make_shard(jnp.asarray(x))
    w = local_window(sh, k=16, coin=jnp.int32(0))
    s, empty = splitter_from_window(w, seed=seed % 1000)
    assert not bool(empty)
    key = int(np.asarray(unlift(s, jnp.uint32)))
    rank = np.searchsorted(x, key)
    assert abs(rank - n // 2) <= 8 + 1   # within the window half-width
