"""Test session config.

The distributed sorting library cannot be exercised on a single device, so
the test session runs with 8 emulated CPU devices (NOT the 512-device
dry-run setting, which stays confined to repro.launch.dryrun per the
project brief).  This must happen before jax initializes its backend —
conftest import precedes all test imports.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np   # noqa: E402
import pytest        # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
