"""Test session config.

The distributed sorting library cannot be exercised on a single device, so
the test session runs with 8 emulated CPU devices (NOT the 512-device
dry-run setting, which stays confined to repro.launch.dryrun per the
project brief).  This must happen before jax initializes its backend —
conftest import precedes all test imports.

Higher emulated PE counts (p = 64–1024) do not need more XLA devices: the
``backend="sim"`` path of ``psort`` vmaps the per-PE bodies over a leading
axis in one process, with grouped collectives chunked into ring steps once
their gather buffers would blow past memory (see ``repro.core.comm``).

Markers: ``slow`` tags the long-tail matrix tests; the default lane
excludes them (``addopts`` in pyproject.toml), so the tier-1 command
``pytest -x -q`` stays fast.  Run ``pytest -m slow`` for the full matrix.
``faults`` tags the fault-injection lane (tests/test_faults.py): CI runs
the small-p slice in the fast job (``-m "faults and not slow"``) and the
full algorithm × distribution fault matrix nightly (``-m slow``).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np   # noqa: E402
import pytest        # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running matrix/scaling tests (excluded from "
        "the default fast lane; run with -m slow)")
    config.addinivalue_line(
        "markers", "faults: fault-injection lane (kill/delay/rescale); the "
        "fast CI slice runs -m 'faults and not slow'")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
