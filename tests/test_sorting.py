"""Core contract: every algorithm × every paper input instance × sizes.

This is the reproduction of the paper's robustness matrix (§VII / Fig. 1):
the robust algorithms must sort *every* instance including Zero, DeterDupl,
Staggered, Mirrored and AllToOne; the non-robust baselines are expected to
fail exactly where the paper says they fail.
"""
import numpy as np
import pytest

from repro.data.distributions import INSTANCES, generate_instance
from helpers import check_sort

ROBUST = ["rquick", "rfis", "rams", "bitonic"]
ALL_INSTANCES = sorted(INSTANCES)


@pytest.mark.parametrize("algorithm", ROBUST)
@pytest.mark.parametrize("instance", ALL_INSTANCES)
def test_robust_all_instances(algorithm, instance):
    p = 8
    for n in (0, 1, 5, 4 * p, 64 * p):
        x = generate_instance(instance, p, n).astype(np.int64)
        check_sort(x.astype(np.int32), p, algorithm,
                   check_balance=(algorithm in ("rquick", "rams", "rfis")))


@pytest.mark.parametrize("algorithm", ["gatherm", "allgatherm"])
@pytest.mark.parametrize("instance", ["Uniform", "Zero", "AllToOne"])
def test_gather_variants(algorithm, instance):
    p = 8
    for n in (0, 1, p // 2, 4 * p):
        x = generate_instance(instance, p, n).astype(np.int32)
        check_sort(x, p, algorithm)


@pytest.mark.parametrize("p", [2, 4, 8])
def test_power_of_two_pe_counts(p):
    x = np.random.default_rng(1).integers(0, 1000, 256).astype(np.int32)
    for algorithm in ROBUST:
        check_sort(x, p, algorithm)


def test_float_and_negative_keys():
    r = np.random.default_rng(2)
    xf = r.normal(size=500).astype(np.float32)
    from repro.core.api import SortConfig, psort
    out = np.asarray(psort(xf, config=SortConfig(p=8, algorithm="rquick")))
    assert (out == np.sort(xf)).all()
    xi = r.integers(-2**31, 2**31, size=500).astype(np.int32)
    check_sort(xi, 8, "rquick")


def test_ssort_duplicate_weakness_matches_paper():
    """The classical sample sort is NOT robust to heavy duplicates (paper
    §VII-B: NTB variants deadlock; our static-capacity analogue
    overflows).  This is an intended negative result."""
    p = 8
    x = generate_instance("Zero", p, 64 * p).astype(np.int32)
    check_sort(x, p, "ssort", expect_overflow=True)


def test_ssort_uniform_ok():
    x = generate_instance("Uniform", 8, 512).astype(np.int32)
    check_sort(x, 8, "ssort")


def test_ntb_quick_fails_on_duplicates():
    """RQuick without tie-breaking degenerates on DeterDupl (Fig. 2a)."""
    p = 8
    x = generate_instance("DeterDupl", p, 64 * p).astype(np.int32)
    from repro.core.api import SortConfig, psort
    out, info = psort(x, config=SortConfig(p=p, algorithm="ntb-quick"),
                      return_info=True)
    # either overflow or gross imbalance must be observed
    assert info["overflow"] > 0 or info["balance"] > 3.0


def test_auto_selection_regimes():
    from repro.core.selection import select_algorithm
    p = 262144
    assert select_algorithm(max(1, p // 243), p) == "gatherm"   # very sparse
    assert select_algorithm(2 * p, p) in ("rfis", "rquick")
    assert select_algorithm(2**10 * p, p) == "rquick"           # small
    assert select_algorithm(2**20 * p, p) == "rams"             # large


def test_auto_psort_small():
    x = np.random.default_rng(3).integers(0, 100, 64).astype(np.int32)
    from repro.core.api import SortConfig, psort
    out, info = psort(x, config=SortConfig(p=8, algorithm="auto"),
                      return_info=True)
    assert (np.asarray(out) == np.sort(x)).all()
