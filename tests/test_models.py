"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes and finite values (brief deliverable f)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, smoke_variant, SHAPES, \
    shape_applicable
from repro.models import transformer as T


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(sc, B, S, key):
    if sc.family == "audio":
        return {"embeds": jax.random.normal(key, (B, S, sc.d_model),
                                            jnp.bfloat16),
                "labels": jax.random.randint(key, (B, S, sc.n_codebooks), 0,
                                             sc.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, sc.vocab),
            "labels": jax.random.randint(key, (B, S), 0, sc.vocab)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch, key):
    sc = smoke_variant(get_config(arch))
    B, S = 2, 64
    params = T.init_params(key, sc)
    batch = _batch(sc, B, S, key)
    logits, aux = jax.jit(lambda p: T.forward(p, batch, sc))(params)
    exp = (B, S, sc.n_codebooks, sc.vocab) if sc.family == "audio" \
        else (B, S, sc.vocab)
    assert logits.shape == exp
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: T.loss_fn(p, batch, sc)))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch, key):
    sc = smoke_variant(get_config(arch))
    B = 2
    params = T.init_params(key, sc)
    st = T.init_decode_state(sc, B, 32, jnp.bfloat16)
    if sc.family == "audio":
        inp = {"embeds": jax.random.normal(key, (B, 1, sc.d_model),
                                           jnp.bfloat16)}
    else:
        inp = {"tokens": jax.random.randint(key, (B, 1), 0, sc.vocab)}
    step = jax.jit(lambda p, s, i: T.decode_step(p, s, i, sc))
    logits, st = step(params, st, inp)
    logits2, st = step(params, st, inp)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())
    assert int(st.pos) == 2


def test_decode_matches_prefill_dense(key):
    """Teacher-forced decode must reproduce the prefill logits (llama)."""
    sc = smoke_variant(get_config("llama3.2-1b"))
    B, S = 1, 8
    params = T.init_params(key, sc)
    toks = jax.random.randint(key, (B, S), 0, sc.vocab)
    full, _ = T.forward(params, {"tokens": toks}, sc)
    st = T.init_decode_state(sc, B, S, jnp.bfloat16)
    outs = []
    for t in range(S):
        lg, st = T.decode_step(params, st, {"tokens": toks[:, t:t + 1]}, sc)
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    ref = np.asarray(full, np.float32)
    # bf16 accumulation differences allowed; ranking must agree
    agree = (dec.argmax(-1) == ref.argmax(-1)).mean()
    assert agree >= 0.7, f"decode/prefill logits diverge (argmax agree {agree})"


def test_param_counts_match_published():
    expected = {"mixtral-8x22b": 141e9, "nemotron-4-340b": 341e9,
                "llama3.2-1b": 1.24e9, "qwen3-14b": 14.8e9,
                "mistral-large-123b": 123e9, "chameleon-34b": 34e9}
    for name, target in expected.items():
        got = get_config(name).param_count()
        assert abs(got - target) / target < 0.06, (name, got, target)


def test_shape_applicability_skips():
    skips = [a for a in ARCHS
             if not shape_applicable(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(skips) == sorted([
        "granite-moe-1b-a400m", "nemotron-4-340b", "llama3.2-1b", "qwen3-14b",
        "mistral-large-123b", "chameleon-34b", "musicgen-large"])
    for a in ("mixtral-8x22b", "zamba2-2.7b", "rwkv6-1.6b"):
        assert shape_applicable(get_config(a), SHAPES["long_500k"])[0]
