"""Runtime: checkpoint roundtrip + atomicity, crash-resume, elastic
resharding, straggler watchdog, gradient compression convergence."""
import os
import shutil
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.failures import (StepWatchdog, flag_stragglers,
                                    run_with_restarts)


def _state(v=0.0):
    return {"w": jnp.full((8, 4), v, jnp.float32),
            "step": jnp.asarray(3, jnp.int32),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32) + v}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    s = _state(1.5)
    mgr.save(7, s)
    out = mgr.restore(jax.eval_shape(lambda: s))
    assert float(out["w"][0, 0]) == 1.5 and int(out["step"]) == 3
    assert mgr.latest_step() == 7


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for k in range(5):
        mgr.save_async(k, _state(float(k)))
    mgr.wait()
    mgr.save(99, _state(9.0))
    steps = mgr.all_steps()
    assert 99 in steps and len(steps) <= 2


def test_checkpoint_atomicity(tmp_path):
    """A dir without _COMMITTED must be ignored (crash during save)."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state(1.0))
    broken = tmp_path / "step_000000099"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    s = _state(2.0)
    mgr.save(1, s)
    leaf = next((tmp_path / "step_000000001").glob("leaf_0.npy"))
    arr = np.load(leaf)
    arr.flat[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError):
        mgr.restore(jax.eval_shape(lambda: s))


def test_elastic_restore_reshards(tmp_path):
    """Save under mesh (4,2), restore under (2,4) — axis-name rules only."""
    devs = jax.devices()[:8]
    mesh_a = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
    mesh_b = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": xa})
    out = mgr.restore({"x": jax.eval_shape(lambda: x)},
                      shardings={"x": NamedSharding(mesh_b,
                                                    P("data", "model"))})
    assert out["x"].sharding.mesh.shape["model"] == 4
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))


def test_crash_resume_end_to_end(tmp_path):
    """Fault injection: training crashes at step 7, recovery resumes from
    the last checkpoint and finishes all steps with a consistent state."""
    from repro.configs import get_config, smoke_variant
    from repro.launch.mesh import make_mesh_shape
    from repro.launch.train import train

    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_mesh_shape((1, 2), ("data", "model"))
    final, losses = train(cfg, mesh, steps=10, batch=2, seq=32,
                          ckpt_dir=tmp_path, ckpt_every=5, crash_at=7,
                          logger=lambda *a: None)
    assert final == 10
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() == 10


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(k_mad=6.0, warmup=5)
    for i in range(20):
        assert not wd.observe(i, 0.1 + 0.001 * (i % 3))
    assert wd.observe(20, 1.0)          # 10× median → straggler
    assert wd.flagged == [20]


def test_watchdog_stop_without_start_raises():
    """Regression: stop() before start() used to TypeError on None - t0."""
    wd = StepWatchdog()
    with pytest.raises(RuntimeError, match="start"):
        wd.stop(0)
    # and stop() consumes the start: a second stop raises again
    wd.start()
    wd.stop(0, now=wd._t0 + 0.1)
    with pytest.raises(RuntimeError, match="start"):
        wd.stop(1)


def test_watchdog_warmup_boundary():
    """Exactly ``warmup`` history entries is the first flaggable step."""
    wd = StepWatchdog(k_mad=6.0, warmup=5)
    for i in range(5):                   # history 0..4 entries: never flags
        assert not wd.observe(i, 0.1)
    # exactly 5 entries of history now — a 100× outlier must flag
    assert wd.observe(5, 10.0)
    assert wd.flagged == [5]
    # boundary from below: a fresh watchdog with warmup-1 history ignores
    # the same outlier
    wd2 = StepWatchdog(k_mad=6.0, warmup=5)
    for i in range(4):
        wd2.observe(i, 0.1)
    assert not wd2.observe(4, 10.0)


def test_watchdog_window_is_100_entries():
    """The estimate tracks the last 100 steps only: after 100+ slow steps
    the old fast regime has scrolled out and slow is the new normal."""
    wd = StepWatchdog(k_mad=6.0, warmup=5)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 10.0)          # slow vs fast history: flags
    for i in range(11, 115):
        wd.observe(i, 10.0)              # regime change
    assert len(wd.times) > 100
    assert not wd.observe(115, 10.0)     # window refilled: no longer flags


def test_flag_stragglers_one_round():
    times = [1.0] * 8
    times[3] = 8.0
    assert flag_stragglers(times) == [3]
    assert flag_stragglers([1.0] * 8) == []
    assert flag_stragglers([]) == []


def test_run_with_restarts_gives_up(tmp_path):
    mgr = CheckpointManager(tmp_path)

    def always_fail(start):
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fail, ckpt_manager=mgr, max_restarts=2,
                          logger=lambda *a: None)


def test_run_with_restarts_no_progress_gives_up_early(tmp_path):
    """A crash that never advances the checkpoint must not burn the whole
    restart budget replaying itself — and the give-up log line must not be
    another 'restart N/max'."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _state())                # progress frozen at step 5
    lines, calls = [], []

    def always_fail(start):
        calls.append(start)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fail, ckpt_manager=mgr, max_restarts=10,
                          logger=lines.append)
    assert len(calls) == 2               # initial try + one retry, not 11
    assert "no progress" in lines[-1]
    assert "restart" not in lines[-1].replace("restarts", "")


def test_run_with_restarts_final_raise_not_logged_as_restart():
    lines = []

    def always_fail(start):
        raise ValueError("boom")

    with pytest.raises(ValueError):
        run_with_restarts(always_fail, max_restarts=2, logger=lines.append,
                          progress_fn=None)
    assert "giving up after 2" in lines[-1]
    assert sum("restart " in ln for ln in lines) == 2   # only real retries


def test_run_with_restarts_retry_on_filters():
    """Exceptions outside retry_on propagate without any retry."""
    calls = []

    def fail(start):
        calls.append(start)
        raise KeyError("boom")

    with pytest.raises(KeyError):
        run_with_restarts(fail, max_restarts=5, retry_on=(ValueError,),
                          logger=lambda *a: None)
    assert len(calls) == 1


def test_run_with_restarts_recovers_with_progress():
    """Progress between failures keeps the retry loop alive."""
    state = {"step": 0}

    def fn(start):
        state["step"] += 1
        if state["step"] < 3:
            raise RuntimeError("boom")
        return "done"

    out = run_with_restarts(fn, max_restarts=5, logger=lambda *a: None,
                            progress_fn=lambda: state["step"])
    assert out == "done" and state["step"] == 3


def test_grad_compression_error_feedback():
    """int8 compressed psum with error feedback: SGD on a quadratic must
    converge to the same optimum as exact gradients."""
    from repro.optim.grad_compress import compressed_psum
    from repro.runtime.compat import shard_map

    p = 4
    devs = jax.devices()[:p]
    mesh = Mesh(np.array(devs), ("data",))
    r = np.random.default_rng(0)
    target = r.normal(size=(32,)).astype(np.float32)
    data = (target[None] + 0.1 * r.normal(size=(p, 32))).astype(np.float32)

    def local_step(w, x, err):
        g = {"w": 2 * (w["w"] - x[0])}
        g, err = compressed_psum(g, err, "data", p)
        return g["w"], err

    w = {"w": jnp.zeros((32,), jnp.float32)}
    err = {"w": jnp.zeros((p, 32), jnp.float32)}
    with mesh:
        stepf = jax.jit(shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P("data"), P("data")),
            out_specs=(P(), P("data"))))
        for _ in range(200):
            g, err = stepf(w, data, err)
            w = {"w": w["w"] - 0.05 * g}
    got = np.asarray(w["w"])
    assert np.abs(got - data.mean(0)).max() < 2e-2


def test_grad_compression_reduces_wire_bytes():
    """The HLO of the compressed path must move ~4× fewer collective bytes
    than an f32 psum of the same gradient."""
    from repro.launch import hlo_cost
    from repro.optim.grad_compress import compressed_psum_mean
    from repro.runtime.compat import shard_map
    p = 4
    mesh = Mesh(np.array(jax.devices()[:p]), ("data",))
    g = jnp.zeros((1 << 16,), jnp.float32)
    e = jnp.zeros((1 << 16,), jnp.float32)

    def comp(g, e):
        return compressed_psum_mean(g, e, "data", p)

    def exact(g, e):
        return jax.lax.psum(g, "data") / p, e

    def wire(fn):
        with mesh:
            c = jax.jit(shard_map(fn, mesh=mesh, in_specs=(P(), P()),
                                  out_specs=(P(), P()))).lower(g, e).compile()
        a = hlo_cost.analyze(c.as_text())
        return sum(a["collective_bytes"].values())

    assert wire(comp) < 0.45 * wire(exact)


def test_grad_compression_sim_backend():
    """compressed_psum_mean routed through repro.core.comm runs on the sim
    backend at p = 64 emulated PEs (no mesh) and approximates the exact
    mean within int8 quantization error."""
    from repro.core import comm
    from repro.optim.grad_compress import compressed_psum_mean

    p = 64
    r = np.random.default_rng(7)
    data = r.normal(size=(p, 33)).astype(np.float32)
    err0 = np.zeros((p, 33), np.float32)

    def body(g, e):
        return compressed_psum_mean(g, e, "data", p)

    out, err = jax.jit(comm.sim_map(body, "data", p))(
        jnp.asarray(data), jnp.asarray(err0))
    out = np.asarray(out)
    want = data.mean(axis=0)
    # two int8 quantization rounds: error bounded by ~2 quantization steps
    tol = 2.5 * (np.abs(data).max() / 127 + np.abs(want).max() / 127)
    assert np.abs(out - want[None]).max() < tol
    assert np.abs(np.asarray(err)).max() > 0    # residual is being tracked


def test_grad_compression_sim_matches_shard_map_bitwise():
    """Same body, two backends: sim at p = 8 must reproduce the shard_map
    result bit for bit (the comm-layer contract of test_differential)."""
    from repro.core import comm
    from repro.optim.grad_compress import compressed_psum_mean
    from repro.runtime.compat import shard_map

    p = 8
    r = np.random.default_rng(3)
    data = r.normal(size=(p, 24)).astype(np.float32)
    err0 = np.zeros((p, 24), np.float32)

    def body(g, e):
        return compressed_psum_mean(g, e, "data", p)

    mesh = Mesh(np.array(jax.devices()[:p]), ("data",))

    def blk(g, e):
        o, ne = body(g[0], e[0])
        return o[None], ne[None]

    with mesh:
        out_sm, err_sm = jax.jit(shard_map(
            blk, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))(jnp.asarray(data),
                                               jnp.asarray(err0))
    out_sim, err_sim = jax.jit(comm.sim_map(body, "data", p))(
        jnp.asarray(data), jnp.asarray(err0))
    np.testing.assert_array_equal(np.asarray(out_sm), np.asarray(out_sim))
    np.testing.assert_array_equal(np.asarray(err_sm), np.asarray(err_sim))


def test_elastic_rescale_plan():
    from repro.configs import get_config
    from repro.runtime.elastic import plan_rescale

    cfg = get_config("qwen3-14b")
    # grow 256 → 512 chips keeping model extent
    p = plan_rescale({"data": 16, "model": 16}, 512, cfg, global_batch=256)
    assert p.n_chips == 512 and p.new_shape["model"] == 16
    assert p.grad_accum == 1             # 256 % 32 == 0: no accumulation
    # shrink to 24 chips: model must divide arch dims (17408, 5120)
    p2 = plan_rescale({"data": 16, "model": 16}, 24, cfg, global_batch=256)
    assert p2.n_chips == 24
    assert cfg.d_ff % p2.new_shape["model"] == 0
    # regression: data extent 3 does not divide 256 — the old formula
    # reported accum=1; the plan must pad up to the next multiple of 3
    assert p2.grad_accum == -(-256 // (p2.new_shape["data"] *
                                       p2.new_shape.get("pod", 1)))
    assert p2.grad_accum > 1
    assert any("accum" in nt for nt in p2.notes)
    # degenerate: 1 chip
    p3 = plan_rescale({"data": 16, "model": 16}, 1, cfg, global_batch=256)
    assert p3.new_shape == {"data": 1, "model": 1}
    assert p3.grad_accum == 1            # 256 % 1 == 0


def test_plan_sort_rescale():
    from repro.runtime.elastic import plan_sort_rescale

    # one failure: survivors rounded down to the next power of two
    r = plan_sort_rescale(8, [2])
    assert (r.p_new, r.survivors, r.failed) == (4, 7, (2,))
    # two failures at p=16 → 14 survivors → p=8
    r2 = plan_sort_rescale(16, (3, 9))
    assert r2.p_new == 8
    # exact power of two survivor count is kept
    r3 = plan_sort_rescale(8, [0, 1, 2, 3])
    assert r3.p_new == 4
    # nested: inner extent preserved while it fits, outer absorbs the cut
    r4 = plan_sort_rescale(16, [5], mesh_shape=(4, 4))
    assert r4.p_new == 8 and r4.mesh_shape == (2, 4)
    r5 = plan_sort_rescale(4, [0, 1, 3], mesh_shape=(2, 2))
    assert r5.p_new == 1 and r5.mesh_shape == (1, 1)
    # out-of-range / duplicate ranks are ignored
    assert plan_sort_rescale(8, [2, 2, 99]).p_new == 4
    with pytest.raises(ValueError):
        plan_sort_rescale(2, [0, 1])


def test_elastic_rescale_state_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, smoke_variant
    from repro.launch.mesh import make_mesh_shape
    from repro.dist.sharding import make_shardings
    from repro.models import transformer as T
    from repro.runtime.elastic import rescale_state

    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh_a = make_mesh_shape((4, 2), ("data", "model"))
    mesh_b = make_mesh_shape((2, 4), ("data", "model"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sh_a = make_shardings(jax.eval_shape(lambda: params), cfg, mesh_a)
    params_a = jax.tree.map(jax.device_put, params, sh_a)
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, params_a)
    restored = rescale_state(params_a, params, cfg, mesh_b, mgr)
    got = np.asarray(jax.tree.leaves(restored)[0], np.float32)
    want = np.asarray(jax.tree.leaves(params)[0], np.float32)
    np.testing.assert_array_equal(got, want)
