"""External-memory psort: the out-of-core lane vs the in-core algorithms.

The differential contract (ISSUE 8): ``psort(..., external=...)`` on a
shard larger than the device budget must produce output **bitwise equal**
to the in-core path — the final key array is *the* globally sorted array,
independent of the (key, tie) schedule the external lane sorts by — for
every algorithm × distribution cell, with the exact multiset preserved
through run formation, the per-run exchanges, and the k-way merge.

Lanes follow the test_differential pattern: the fast slice runs the core
instance set (duplicate-heavy + skewed) at p = 8 with 2–8 runs per PE;
the full 7-algorithm × 11-distribution matrix is ``slow`` and runs
nightly.  Unit/property sections cover the pass primitives directly:
run-formation round-trips, merge ≡ sorted concatenation (both engines),
the sketch-provisioned run-slice capacity invariant, and the kway
pad-accounting regression.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ExternalPolicy, psort, select_algorithm
from repro.core.api import SortConfig, trace_collectives
from repro.core import external as ext
from repro.core.selection import CostModel, cost_external, regime_table
from repro.data.distributions import INSTANCES, generate_instance

from helpers import check_sort

ALGOS = ["gatherm", "allgatherm", "rfis", "rquick", "rams", "bitonic",
         "ssort"]
ALL_INSTANCES = sorted(INSTANCES)
CORE_INSTANCES = ["Uniform", "Zero", "g-Group", "Staggered"]
# classical sample sort's duplicate-key overflow is a property of the
# algorithm, not of the external lane — same exclusions as the in-core
# differential matrix
SSORT_SKIP = {"Zero", "DeterDupl", "RandDupl", "Mirrored"}

P = 8


def _cells():
    for algorithm in ALGOS:
        for instance in ALL_INSTANCES:
            if algorithm == "ssort" and instance in SSORT_SKIP:
                continue
            marks = [] if instance in CORE_INSTANCES else [pytest.mark.slow]
            yield pytest.param(algorithm, instance, marks=marks,
                               id=f"{algorithm}-{instance}")


@pytest.mark.parametrize("algorithm,instance", _cells())
def test_external_matches_incore_bitwise(algorithm, instance):
    """external output == in-core output == np.sort, bitwise, at ~5 runs
    per PE (per = 37, budget = 8)."""
    x = generate_instance(instance, P, 37 * P).astype(np.int32)
    out_ic = np.asarray(psort(x, config=SortConfig(
        p=P, algorithm=algorithm, backend="sim")))
    out_ex, info = psort(x, config=SortConfig(
        p=P, backend="sim", external=ExternalPolicy(budget=8)),
        return_info=True)
    out_ex = np.asarray(out_ex)
    assert info["algorithm"] == "external"
    assert info["overflow"] == 0
    assert (out_ex == out_ic).all()
    assert (out_ex == np.sort(x)).all()
    # exact multiset: the carried idx payload is a permutation
    assert len(np.unique(info["perm"])) == len(x)


@pytest.mark.parametrize("runs", [2, 3, 5, 8])
def test_external_run_count_sweep(runs):
    """2–8 runs per PE, same answer every time (per = 40)."""
    x = generate_instance("Staggered", P, 40 * P).astype(np.int32)
    budget = -(-40 // runs)
    out, info = psort(x, config=SortConfig(
        p=P, backend="sim", external=ExternalPolicy(budget=budget)),
        return_info=True)
    assert info["external"]["runs"] == runs
    assert (np.asarray(out) == np.sort(x)).all()


def test_external_wide_key_path():
    """u64 keys (int64 beyond the u32 range) take the plane/lexsort path."""
    rng = np.random.default_rng(7)
    x = rng.integers(-2**62, 2**62, size=200, dtype=np.int64)
    out = psort(x, config=SortConfig(p=4, backend="sim",
                               external=ExternalPolicy(budget=8)))
    assert (np.asarray(out) == np.sort(x)).all()


def test_external_losertree_engine_matches_classifier():
    x = generate_instance("g-Group", P, 37 * P).astype(np.int32)
    a = np.asarray(psort(x, config=SortConfig(
        p=P, backend="sim", external=ExternalPolicy(budget=8))))
    b = np.asarray(psort(x, config=SortConfig(
        p=P, backend="sim",
        external=ExternalPolicy(budget=8, merge="losertree"))))
    assert (a == b).all() and (a == np.sort(x)).all()


def test_external_deterministic():
    x = generate_instance("RandDupl", P, 37 * P).astype(np.int32)
    pol = ExternalPolicy(budget=8)
    cfg = SortConfig(p=P, backend="sim", external=pol)
    a = np.asarray(psort(x, config=cfg))
    b = np.asarray(psort(x, config=cfg))
    assert (a == b).all()


@pytest.mark.parametrize("n", [0, 1, 7, 37])
def test_external_degenerate_sizes(n):
    """n < p, n < budget, empty input."""
    x = np.arange(n, dtype=np.int32)[::-1].copy()
    out = psort(x, config=SortConfig(
        p=4, backend="sim",
        external=ExternalPolicy(budget=4, slot_factor=2.0)))
    assert (np.asarray(out) == np.sort(x)).all()


def test_external_8x_budget_acceptance():
    """Acceptance: n/p >= 8× the device budget sorts correctly."""
    p = 4
    x = generate_instance("Uniform", p, 128 * p).astype(np.int32)
    out, info = psort(x, config=SortConfig(
        p=p, backend="sim", external=ExternalPolicy(budget=16)),
        return_info=True)
    assert info["external"]["runs"] == 8
    assert (np.asarray(out) == np.sort(x)).all()


def test_external_env_flag(monkeypatch):
    monkeypatch.setenv("REPRO_EXTERNAL_BUDGET", "8")
    x = generate_instance("Uniform", 4, 32 * 4).astype(np.int32)
    out, info = psort(x, config=SortConfig(p=4, backend="sim"),
                      return_info=True)
    assert info["algorithm"] == "external"
    assert (np.asarray(out) == np.sort(x)).all()


def test_external_policy_validation():
    with pytest.raises(ValueError, match="budget"):
        ExternalPolicy(budget=0)
    with pytest.raises(ValueError, match="merge"):
        ExternalPolicy(budget=4, merge="heapsort")
    with pytest.raises(ValueError, match="sketch_per_run"):
        ExternalPolicy(budget=4, sketch_per_run=0)
    with pytest.raises(ValueError, match="sim"):
        psort(np.arange(8, dtype=np.int32),
              config=SortConfig(p=2, backend="shard_map",
                                external=ExternalPolicy(budget=2)))
    with pytest.raises(ValueError, match="external"):
        psort(np.arange(8, dtype=np.int32),
              config=SortConfig(p=2, backend="sim",
                                algorithm="external"))


# ---------------------------------------------------------------------------
# CommTrace: per-pass phase attribution and io accounting
# ---------------------------------------------------------------------------


def test_trace_per_pass_attribution():
    t = trace_collectives(256, SortConfig(
        p=4, external=ExternalPolicy(budget=16)))
    tags = set(t.tags())
    assert {"ext:splitters", "ext:pass0", "ext:pass3", "ext:merge"} <= tags
    # every pass moved wire bytes through the slotted a2a
    for r in range(4):
        sub = t.filter(tag=f"ext:pass{r}")
        assert sub.filter(primitive="all_to_all").wire_bytes() > 0
    # io pseudo-events: run formation + merge streaming, both directions,
    # excluded from wire aggregates
    assert t.io_bytes() > 0
    assert t.filter(tag="ext:runs").io_bytes() > 0
    assert t.filter(tag="ext:merge").io_bytes() > 0
    io_prims = {e.primitive for e in t.events
                if e.primitive in t.IO_PRIMITIVES}
    assert io_prims == {"ext:h2d", "ext:d2h"}
    assert t.wire_bytes() == sum(e.bytes for e in t.events
                                 if e.primitive in t.PRIMITIVES)


def test_trace_double_buffer_io_invariant():
    """Double buffering reorders the copies but moves the same bytes."""
    t1 = trace_collectives(256, SortConfig(
        p=4, external=ExternalPolicy(budget=16)))
    t2 = trace_collectives(256, SortConfig(p=4, external=ExternalPolicy(
        budget=16, double_buffer=False)))
    assert t1.io_bytes() == t2.io_bytes()
    assert t1.wire_bytes() == t2.wire_bytes()


# ---------------------------------------------------------------------------
# pass primitives: run formation, merge, capacity invariant
# ---------------------------------------------------------------------------


def _mk_runs(rng, lens, hi=1 << 20):
    """Sorted (key, tie, idx) runs obeying the pipeline invariant:
    globally unique idx, tie == _mix32(idx) (the merge engine recomputes
    the tie from the carried idx), each run lex-sorted by (key, tie)."""
    total = sum(lens)
    ids = rng.permutation(total).astype(np.uint32)
    runs, off = [], 0
    for n in lens:
        i = ids[off:off + n]
        off += n
        k = rng.integers(0, hi, size=n, dtype=np.int64).astype(np.uint32)
        t = np.asarray(ext._mix32(jnp.asarray(i)))
        order = np.lexsort((t, k))
        runs.append((k[order], t[order], i[order]))
    return runs


def test_form_runs_round_trip():
    rng = np.random.default_rng(11)
    for n, b in [(0, 4), (3, 8), (8, 8), (37, 8), (64, 16), (65, 16)]:
        keys = rng.integers(0, 1 << 31, size=n, dtype=np.int64) \
            .astype(np.uint32)
        idx = np.arange(n, dtype=np.uint32)
        runs = ext.form_runs(keys, idx, budget=b)
        assert len(runs) == max(1, -(-n // b))
        got = np.concatenate([r[2] for r in runs]) if n else np.zeros(0)
        assert sorted(got.tolist()) == list(range(n))
        for k, t, i in runs:
            comp = (k.astype(np.uint64) << np.uint64(32)) | t
            assert (np.sort(comp) == comp).all()


def test_merge_runs_equals_sorted_concat():
    rng = np.random.default_rng(13)
    for engine in ("classifier", "losertree"):
        runs = _mk_runs(rng, (0, 1, 17, 40, 3))
        k, t, i = ext.merge_runs(runs, budget=16, merge=engine)
        ck = np.concatenate([r[0] for r in runs])
        ct = np.concatenate([r[1] for r in runs])
        ref = np.lexsort((ct, ck))
        assert (k == ck[ref]).all() and (t == ct[ref]).all()


def test_merge_runs_all_empty():
    k, t, i = ext.merge_runs([(np.zeros(0, np.uint32),) * 3], budget=8)
    assert len(k) == 0


def test_provision_bound_holds():
    """The run-slice capacity invariant: |run ∩ interval| <= (q+2)·g for
    arbitrary splitters — the proof obligation behind the static slots."""
    rng = np.random.default_rng(17)
    for trial in range(50):
        n = int(rng.integers(1, 300))
        k, t, _ = _mk_runs(rng, (n,), hi=int(rng.integers(2, 1 << 16)))[0]
        s = int(rng.integers(1, 40))
        qk, qt, g = ext.run_sketch(k, t, s)
        nb = int(rng.integers(2, 12))
        sp = np.sort(rng.integers(0, 1 << 16, size=nb - 1,
                                  dtype=np.int64)).astype(np.uint32)
        st_ = rng.integers(0, 1 << 32, size=nb - 1,
                           dtype=np.int64).astype(np.uint32)
        order = np.lexsort((st_, sp))
        sp, st_ = sp[order], st_[order]
        cap = ext.provision(qk, qt, g, sp, st_, nb)
        b = ext.np_bucket(k, t, sp, st_)
        actual = np.bincount(b, minlength=nb)
        assert (actual <= cap).all(), (trial, actual, cap)


def test_external_never_overflows_on_skew():
    """End to end: the sketch-provisioned slots hold on the adversarial
    distributions at the proven slot_factor=1.0."""
    for instance in ("AllToOne", "Zero", "Staggered", "DeterDupl"):
        x = generate_instance(instance, P, 37 * P).astype(np.int32)
        _, info = psort(x, config=SortConfig(
            p=P, backend="sim", external=ExternalPolicy(budget=8)),
            return_info=True)
        assert info["overflow"] == 0, instance


# ---------------------------------------------------------------------------
# classifier engine: kernel vs jnp fallback, kway pad-accounting regression
# ---------------------------------------------------------------------------


def test_classify_kernel_matches_jnp_at_block_size():
    """At C >= _BLOCK the Pallas kway kernel and the jnp lex compare must
    agree bitwise (interpret mode off-TPU)."""
    from repro.kernels.kway import ops as kway_ops
    rng = np.random.default_rng(19)
    C = kway_ops._BLOCK
    k = rng.integers(0, 1 << 32, size=C, dtype=np.int64).astype(np.uint32)
    t = rng.integers(0, 1 << 32, size=C, dtype=np.int64).astype(np.uint32)
    sp = np.sort(rng.integers(0, 1 << 32, size=7,
                              dtype=np.int64)).astype(np.uint32)
    st_ = rng.integers(0, 1 << 32, size=7, dtype=np.int64).astype(np.uint32)
    a = ext._classify_jit(jnp.asarray(k), jnp.asarray(t), jnp.int32(C),
                          jnp.asarray(sp), jnp.asarray(st_), nb=8,
                          use_kernel=True)
    b = ext._classify_jit(jnp.asarray(k), jnp.asarray(t), jnp.int32(C),
                          jnp.asarray(sp), jnp.asarray(st_), nb=8,
                          use_kernel=False)
    assert (np.asarray(a) == np.asarray(b)).all()
    assert (np.asarray(a) == ext.np_bucket(k, t, sp, st_)).all()


def test_kway_pad_accounting_regression():
    """Regression (ISSUE 8 satellite): when the pad exceeds the true
    last-bucket population, the histogram must clamp at zero — and the
    pads must be subtracted from the bucket they actually land in
    (len(s_keys)), not blindly from n_buckets-1."""
    from repro.kernels.kway import kway_classify
    from repro.kernels.kway.ref import kway_classify_ref
    from repro.kernels.kway import ops as kway_ops
    rng = np.random.default_rng(23)
    # C chosen so pad = _BLOCK - C is large; keys all BELOW every
    # splitter → true last-bucket count is 0 and the old accounting
    # underflowed it to -pad
    C = kway_ops._BLOCK + 7              # pad = _BLOCK - 7 >> any bucket
    k = rng.integers(0, 1 << 8, size=C, dtype=np.int64).astype(np.uint32)
    t = rng.integers(0, 1 << 32, size=C, dtype=np.int64).astype(np.uint32)
    # 2 splitters with n_buckets=4: pads land in bucket len(s_keys)=2,
    # NOT n_buckets-1=3 — the old accounting drove hist[3] to -pad
    for sp in (np.array([1 << 10, 1 << 12], np.uint32),
               np.array([1 << 10, 1 << 12, 1 << 14], np.uint32)):
        st_ = np.zeros(sp.shape[0], np.uint32)
        b, h = kway_classify(jnp.asarray(k), jnp.asarray(t),
                             jnp.asarray(sp), jnp.asarray(st_),
                             n_buckets=4, use_kernel=True)
        br, hr = kway_classify_ref(jnp.asarray(k), jnp.asarray(t),
                                   jnp.asarray(sp), jnp.asarray(st_),
                                   n_buckets=4)
        assert (np.asarray(h) >= 0).all()
        assert (np.asarray(h) == np.asarray(hr)).all()
        assert (np.asarray(b) == np.asarray(br)).all()
        assert int(np.asarray(h).sum()) == C


def test_kway_sub_block_fallback():
    """Below _BLOCK the dispatcher takes the reference path (mirrors the
    PR 7 partition fallback tests)."""
    from repro.kernels.kway import kway_classify
    from repro.kernels.kway.ref import kway_classify_ref
    rng = np.random.default_rng(29)
    k = rng.integers(0, 1 << 16, size=100, dtype=np.int64).astype(np.uint32)
    t = rng.integers(0, 1 << 32, size=100, dtype=np.int64).astype(np.uint32)
    sp = np.array([100, 1000, 10000], np.uint32)
    st_ = np.zeros(3, np.uint32)
    b, h = kway_classify(jnp.asarray(k), jnp.asarray(t), jnp.asarray(sp),
                         jnp.asarray(st_), n_buckets=4, use_kernel=True)
    br, hr = kway_classify_ref(jnp.asarray(k), jnp.asarray(t),
                               jnp.asarray(sp), jnp.asarray(st_),
                               n_buckets=4)
    assert (np.asarray(b) == np.asarray(br)).all()
    assert (np.asarray(h) == np.asarray(hr)).all()


# ---------------------------------------------------------------------------
# selection: the external regime
# ---------------------------------------------------------------------------


def test_selection_external_regime():
    assert select_algorithm(1 << 20, 8, budget=1 << 10) == "external"
    assert select_algorithm(64, 8, budget=1 << 10) != "external"
    assert select_algorithm(1 << 20, 8) != "external"      # no budget, no cap
    rows = regime_table(8, exponents=range(0, 24), budget=1 << 12)
    algos = [a for _, _, a in rows]
    assert algos[-1] == "external"
    # the crossover is monotone: once external, always external
    first = algos.index("external")
    assert all(a == "external" for a in algos[first:])


def test_cost_external_model_fields():
    m = CostModel(io_beta=1e-9, overlap=0.5)
    base = CostModel(io_beta=1e-9, overlap=0.0)
    assert m.io_b == 1e-9
    assert CostModel().io_b > 0                 # PCIe prior fallback
    n, p, b = 1 << 22, 8, 1 << 16
    assert cost_external(n, p, b, model=m) < cost_external(n, p, b,
                                                           model=base)
    assert cost_external(n, p, b) > 0
    # JSON round-trip carries the new fields
    m2 = CostModel.from_json(m.to_json())
    assert m2.io_beta == 1e-9 and m2.overlap == 0.5
    # profiles predating the external regime still load
    legacy = CostModel.from_json(CostModel().to_json().replace(
        '"io_beta": null,', '').replace('"overlap": 0.0,', ''))
    assert legacy.io_beta is None


# ---------------------------------------------------------------------------
# hypothesis properties (optional dependency, mirrors test_property.py)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # optional dep — mirror the
    given = None                          # test_property.py convention

if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 200), st.integers(1, 64), st.integers(0, 10**9))
    def test_prop_form_runs_round_trip(n, budget, seed):
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, 1 << 32, size=n, dtype=np.int64) \
            .astype(np.uint32)
        runs = ext.form_runs(keys, np.arange(n, dtype=np.uint32),
                             budget=budget)
        assert len(runs) == max(1, -(-n // budget))
        idx = np.concatenate([r[2] for r in runs]) if n \
            else np.zeros(0, np.uint32)
        assert sorted(idx.tolist()) == list(range(n))
        got = np.concatenate([r[0] for r in runs]) if n \
            else np.zeros(0, np.uint32)
        assert sorted(got.tolist()) == sorted(keys.tolist())

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(0, 60), min_size=1, max_size=6),
           st.integers(1, 32),
           st.sampled_from(["classifier", "losertree"]),
           st.integers(0, 10**9))
    def test_prop_merge_equals_sorted_concat(lens, budget, engine, seed):
        rng = np.random.default_rng(seed)
        runs = _mk_runs(rng, lens, hi=64)             # duplicate-heavy
        k, t, i = ext.merge_runs(runs, budget=budget, merge=engine)
        ck = np.concatenate([r[0] for r in runs])
        ct = np.concatenate([r[1] for r in runs])
        ref = np.lexsort((ct, ck))
        assert (k == ck[ref]).all() and (t == ct[ref]).all()

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 250), st.integers(1, 40), st.integers(2, 12),
           st.integers(0, 10**9))
    def test_prop_sketch_provision_never_overflows(n, s, nb, seed):
        rng = np.random.default_rng(seed)
        k, t, _ = _mk_runs(rng, (n,), hi=256)[0]      # adversarial dups
        qk, qt, g = ext.run_sketch(k, t, s)
        sp = np.sort(rng.integers(0, 256, size=nb - 1,
                                  dtype=np.int64)).astype(np.uint32)
        st_ = np.zeros(nb - 1, np.uint32)
        cap = ext.provision(qk, qt, g, sp, st_, nb)
        actual = np.bincount(ext.np_bucket(k, t, sp, st_), minlength=nb)
        assert (actual <= cap).all()
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_external_properties():
        pass
