"""§Perf variants must be *semantics-preserving*: each optimized path is
checked against its baseline (the optimizations change schedules and
shardings, never results)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config, smoke_variant
from repro.models import attention as A
from repro.models import moe as M
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh24():
    return Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))


def test_context_parallel_attention_matches_dense(mesh24):
    cfg = dataclasses.replace(smoke_variant(get_config("qwen3-14b")),
                              attn_context_parallel=True)
    key = jax.random.PRNGKey(0)
    p = A.init_attention(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, cfg.qk_norm, jnp.float32)
    x = jax.random.normal(key, (2, 256, cfg.d_model), jnp.float32)
    ref = A.attention(x, p, cfg, block=512)        # dense path
    with mesh24:
        cp = jax.jit(lambda xx: A.attention(xx, p, cfg, block=64,
                                            mesh=mesh24))(x)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(cp, np.float32), atol=3e-3)


def test_banded_swa_matches_masked(mesh24):
    cfg = dataclasses.replace(smoke_variant(get_config("mixtral-8x22b")),
                              sliding_window=32)
    key = jax.random.PRNGKey(1)
    p = A.init_attention(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, cfg.qk_norm, jnp.float32)
    x = jax.random.normal(key, (1, 256, cfg.d_model), jnp.float32)
    full = A.attention(x, p, cfg, block=64, banded=False)
    band = A.attention(x, p, cfg, block=64, banded=True)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(band, np.float32), atol=3e-3)


def test_moe_tp_shardmap_matches_dense(mesh24):
    cfg = dataclasses.replace(smoke_variant(get_config("mixtral-8x22b")),
                              moe_tp_fused=True)
    key = jax.random.PRNGKey(2)
    p = M.init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    yd, _ = M.moe_dense(x, p, cfg)
    with mesh24:
        yt, _ = jax.jit(lambda xx: M.moe_tp_shardmap(
            xx, p, cfg, mesh24, data_axes=("data",),
            capacity_factor=8.0))(x)
    np.testing.assert_allclose(np.asarray(yd, np.float32),
                               np.asarray(yt, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_seq_parallel_forward_matches(mesh24):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    cfg_sp = dataclasses.replace(cfg, act_seq_shard=True)
    key = jax.random.PRNGKey(3)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    with mesh24:
        base, _ = jax.jit(lambda pp: T.forward(pp, {"tokens": toks}, cfg,
                                               mesh24))(params)
        sp, _ = jax.jit(lambda pp: T.forward(pp, {"tokens": toks}, cfg_sp,
                                             mesh24))(params)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(sp, np.float32), atol=3e-2,
                               rtol=3e-2)


def test_prefill_last_only_matches_full(mesh24):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    key = jax.random.PRNGKey(4)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    full, _ = T.forward(params, {"tokens": toks}, cfg)
    last, _ = T.forward(params, {"tokens": toks}, cfg, last_only=True)
    np.testing.assert_allclose(np.asarray(full[:, -1:], np.float32),
                               np.asarray(last, np.float32), atol=1e-3)
