"""Config system: model configs (one per assigned architecture), input
shapes, and reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_impl: str = "sort"            # sort | dense
    moe_tp_fused: bool = False        # §Perf: shard_map TP-MoE (psum tokens,
                                      # not the capacity buffer)
    # attention
    sliding_window: Optional[int] = None
    qk_norm: bool = False
    act: str = "silu"                 # silu (gated) | relu2 | gelu
    rope_theta: float = 1e6
    swa_banded: bool = False          # §Perf: skip out-of-window KV blocks
    prefill_last_only: bool = False   # §Perf: slice last token before head
    act_seq_shard: bool = False       # §Perf: sequence-parallel activations
                                      # (scan carry sharded over model)
    attn_context_parallel: bool = False  # §Perf: shard query blocks over
                                         # model (any head count)
    ddp: bool = False                 # §Perf: replicate weights, batch over
                                      # data×model (small-model regime)
    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    attn_every: int = 0               # zamba2 shared block period
    # audio
    n_codebooks: int = 0
    # misc
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"               # none | dots | full
    optimizer: str = "adamw"          # adamw | adafactor
    # which paper algorithm backs MoE dispatch / data pipeline sorting
    sort_algorithm: str = "auto"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or bool(self.sliding_window)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        emb = V * d if self.family != "audio" else 0
        head = (self.n_codebooks or 1) * d * V if not self.tie_embeddings else 0
        if self.family == "ssm":                    # rwkv6
            per = 5 * d * d + 2 * d * f + d * 64 * 2   # time + channel + lora
        elif self.family == "hybrid":               # zamba2 mamba layers
            di = 2 * d
            per = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * d
            shared = 2 * d * (H + 2 * KV) * hd + (H * hd) * d + 3 * d * f
            return emb + head + L * per + shared
        else:
            attn = d * (H + 2 * KV) * hd + H * hd * d
            if self.family == "moe":
                per = attn + self.n_experts * 3 * d * f + d * self.n_experts
            else:
                nmat = 3 if self.act == "silu" else 2
                per = attn + nmat * d * f
        return emb + head + L * per

    def active_param_count(self) -> int:
        """6·N_active·D convention for MoE rooflines."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (H + 2 * KV) * hd + H * hd * d
        act = attn + self.top_k * 3 * d * f + d * self.n_experts
        emb = self.vocab * d + (0 if self.tie_embeddings else self.d_model * self.vocab)
        return emb + L * act


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Per the brief: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md)"
    return True, ""


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2,
        d_ff=128, vocab=256, head_dim=16, remat="none")
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2)
    if cfg.family == "hybrid":
        kw.update(ssm_state=16, ssm_heads=8, attn_every=1, n_kv_heads=4)
    if cfg.family == "ssm":
        kw.update(n_kv_heads=4)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.family == "audio":
        kw.update(n_codebooks=cfg.n_codebooks)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
