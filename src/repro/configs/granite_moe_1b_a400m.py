"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32 experts top-8 — true expert parallelism: the paper's distributed
sort-based dispatch runs over the model axis (32 % 16 == 0)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155, n_experts=32, top_k=8,
    act="silu", tie_embeddings=True,
)
