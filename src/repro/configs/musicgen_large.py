"""musicgen-large [arXiv:2306.05284; hf] — decoder-only over EnCodec
tokens, 4 codebooks × 2048 vocab.  The EnCodec frontend is a stub:
input_specs() provides precomputed frame embeddings (B,S,d); the model owns
4 output heads and the delay-pattern loss surface."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, n_codebooks=4, act="gelu",
)
