"""chameleon-34b [arXiv:2405.09818] — early fusion VLM: text and VQ image
tokens share one 65536 vocabulary, so the backbone consumes a single token
stream (the VQ tokenizer frontend is a stub; input_specs provides ids).
Chameleon uses qk-norm for training stability."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, act="silu", qk_norm=True,
)
