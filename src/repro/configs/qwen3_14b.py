"""qwen3-14b [hf:Qwen/Qwen3-14B] — qk_norm, GQA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, act="silu", qk_norm=True,
    head_dim=128,
)
