"""Architecture registry: --arch <id> → ModelConfig."""
from . import (chameleon_34b, granite_moe_1b_a400m, llama3_2_1b,
               mistral_large_123b, mixtral_8x22b, musicgen_large,
               nemotron_4_340b, qwen3_14b, rwkv6_1_6b, zamba2_2_7b)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    mixtral_8x22b, granite_moe_1b_a400m, nemotron_4_340b, llama3_2_1b,
    qwen3_14b, mistral_large_123b, chameleon_34b, zamba2_2_7b,
    musicgen_large, rwkv6_1_6b)}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
