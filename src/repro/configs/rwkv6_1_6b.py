"""rwkv6-1.6b (Finch) [arXiv:2404.05892] — attention-free, data-dependent
per-channel decay; chunked WKV.  heads = d/64 = 32."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, act="relu2",
)
