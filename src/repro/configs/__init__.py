from .registry import get_config, list_archs, ARCHS        # noqa: F401
from .base import SHAPES, ShapeConfig, ModelConfig, shape_applicable, smoke_variant  # noqa: F401
