"""nemotron-4-340b [arXiv:2402.16819] — GQA, squared-ReLU, 340B params.
Adafactor: Adam's 12 B/param does not fit 256×16 GiB (DESIGN.md §6)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, act="relu2", optimizer="adafactor",
    rope_theta=1e4,
)
