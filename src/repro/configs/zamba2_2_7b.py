"""zamba2-2.7b [arXiv:2411.15242; hf] — Mamba2 backbone + one *shared*
attention(+MLP) block applied every 6 mamba layers.  ssm_state=64,
ssm heads: d_inner=2·2560=5120, head_dim 64 → 80 heads."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, ssm_state=64, ssm_heads=80,
    attn_every=6, act="gelu",
)
