"""Failure recovery and straggler mitigation.

``run_with_restarts`` is the crash-recovery loop: it runs a function and,
on a retryable exception, restarts it — classically from the latest
committed checkpoint (the launcher-side training loop, combined with the
deterministic per-step data pipeline this gives exactly-once step
semantics modulo the steps since the last checkpoint), but the loop is
generic: ``psort``'s fault-tolerance lane (``core/api.py``) drives it with
``retry_on=(PEFailure,)`` and an ``on_failure`` hook that rescales the
sort mesh between attempts.  Two give-up conditions bound the retries: the
``max_restarts`` budget, and *no progress between consecutive restarts*
(a crash that destroys checkpoint progress would otherwise burn the whole
budget replaying the same failure).

``StepWatchdog`` is the straggler detector: it tracks a robust step-time
estimate (median + MAD over the last 100 steps) and flags steps exceeding
``k_mad`` deviations — the signal a deployment uses to trigger re-dispatch
of a slow host's shard or to exclude a failing node at the next elastic
restart.  :func:`flag_stragglers` applies it to one round of per-PE step
times (the psort fault lane: a delayed PE past ``k_mad`` goes down the
same exclude-and-rescale path as a dead one).

``FaultPolicy`` is the user-facing configuration of that lane: the
:class:`repro.core.comm.FaultPlan` to execute, the retry budget, and the
watchdog thresholds; after a run the driver leaves the merged
``CommTrace`` on ``policy.trace`` and a per-attempt log on
``policy.attempts``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np


class StepWatchdog:
    """Median + MAD straggler detector over a sliding 100-step window.

    ``observe(step, dt)`` returns True when ``dt`` exceeds the window
    median by ``k_mad`` MADs *and* by 50 % — the double guard keeps a
    constant-rate stream (MAD ≈ 0) from flagging on noise.  The first
    ``warmup`` observations build history and never flag; the window
    holds the most recent 100 durations, so a regime change (deliberate
    slowdown, different batch shape) stops flagging once the window
    refills.
    """

    def __init__(self, k_mad: float = 6.0, warmup: int = 5):
        self.times: List[float] = []
        self.k_mad = k_mad
        self.warmup = warmup
        self.flagged: List[int] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, *, now: Optional[float] = None) -> bool:
        """Record step duration; returns True when flagged as straggler.

        Each ``stop`` consumes the preceding :meth:`start` — calling it
        without one is a usage bug and raises instead of a bare
        ``TypeError`` on the ``None`` arithmetic.
        """
        if self._t0 is None:
            raise RuntimeError(
                "StepWatchdog.stop() called without a matching start(); "
                "call start() at the beginning of the step being timed")
        dt = (now if now is not None else time.perf_counter()) - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        hist = self.times[-100:]
        self.times.append(dt)
        if len(hist) < self.warmup:
            return False
        med = sorted(hist)[len(hist) // 2]
        mad = sorted(abs(t - med) for t in hist)[len(hist) // 2] + 1e-9
        if dt > med + self.k_mad * mad and dt > 1.5 * med:
            self.flagged.append(step)
            return True
        return False


def flag_stragglers(step_times: Sequence[float], *, k_mad: float = 6.0,
                    warmup: int = 5) -> List[int]:
    """Indices of straggling entries in one round of per-PE step times.

    Drives the ``psort`` fault lane: a :class:`StepWatchdog` is warmed on
    the round's median (so a single round suffices), then each PE's time
    is observed in rank order — a PE stretched past ``k_mad`` MADs flags,
    a constant round never does.
    """
    times = [float(t) for t in step_times]
    if not times:
        return []
    wd = StepWatchdog(k_mad=k_mad, warmup=warmup)
    med = float(np.median(times))
    for _ in range(max(1, wd.warmup)):
        wd.observe(-1, med)
    return [i for i, dt in enumerate(times) if wd.observe(i, dt)]


@dataclasses.dataclass
class FaultPolicy:
    """Configuration of ``psort(..., fault_policy=...)`` (core/api.py).

    ``plan`` is the :class:`repro.core.comm.FaultPlan` executed by
    :class:`repro.core.comm.FaultyCollectives` while each attempt is
    traced; ``max_restarts`` bounds the exclude-and-rescale retries;
    ``k_mad`` / ``warmup`` / ``base_step_time`` parameterize the
    straggler lane (per-PE simulated step times are ``base_step_time``
    stretched by the fired delay factors, scanned by
    :func:`flag_stragglers`).

    The driver writes results back: ``trace`` holds the merged
    ``CommTrace`` across attempts (injected events + regular launches +
    ``rescale`` markers), ``attempts`` one dict per attempt with the
    topology and algorithm it ran.  Use a fresh policy (or at least a
    fresh ``trace``) per ``psort`` call.
    """

    plan: Any = None                     # comm.FaultPlan (duck-typed)
    max_restarts: int = 3
    k_mad: float = 6.0
    warmup: int = 5
    base_step_time: float = 1.0
    logger: Optional[Callable] = None
    trace: Any = None                    # comm.CommTrace, set by the driver
    attempts: List[Dict] = dataclasses.field(default_factory=list)


def run_with_restarts(train_fn: Callable[[int], Any], *, ckpt_manager=None,
                      max_restarts: int = 3, logger=print,
                      retry_on=(Exception,),
                      on_failure: Optional[Callable] = None,
                      progress_fn: Optional[Callable[[], Any]] = None):
    """Run ``train_fn(start) -> result`` with bounded crash recovery.

    ``train_fn`` receives the current progress marker (the latest
    committed checkpoint step when ``ckpt_manager`` is given, else the
    attempt index) and must be resumable from it.  Retries are bounded
    two ways:

      * ``max_restarts`` — the overall budget;
      * **no progress between consecutive restarts** — when the progress
        marker (default ``ckpt_manager.latest_step()``) did not advance
        since the previous failure, retrying would replay the identical
        crash, so the loop gives up early and re-raises.

    ``retry_on`` restricts which exceptions trigger recovery (anything
    else propagates immediately); ``on_failure(exc, restarts)`` runs
    before each retry — the elastic hook where ``psort`` re-plans its
    topology (``repro.runtime.elastic.plan_sort_rescale``).  The final
    re-raise is logged as a give-up, never as another "restart N/max".
    """
    if progress_fn is None and ckpt_manager is not None:
        progress_fn = lambda: (ckpt_manager.latest_step() or 0)  # noqa: E731
    restarts = 0
    prev_progress = None
    while True:
        start = progress_fn() if progress_fn is not None else restarts
        try:
            return train_fn(start)
        except KeyboardInterrupt:
            raise
        except retry_on as e:  # noqa: BLE001 — retry_on scopes the recovery
            restarts += 1
            progress = progress_fn() if progress_fn is not None else None
            if restarts > max_restarts:
                logger(f"[failures] giving up after {max_restarts} "
                       f"restart(s) ({type(e).__name__}: {e})")
                raise
            if prev_progress is not None and progress is not None \
                    and progress <= prev_progress:
                logger(f"[failures] no progress between restarts (stuck at "
                       f"{progress}); giving up ({type(e).__name__}: {e})")
                raise
            prev_progress = progress
            logger(f"[failures] step crashed ({type(e).__name__}: {e}); "
                   f"restart {restarts}/{max_restarts} from "
                   f"{progress if progress is not None else start}")
            if on_failure is not None:
                on_failure(e, restarts)
