"""Failure recovery and straggler mitigation.

``run_with_restarts`` is the launcher-side crash-recovery loop: it runs the
training function, and on any exception restores the latest committed
checkpoint and resumes from that step.  Combined with the deterministic
per-step data pipeline this gives exactly-once step semantics (modulo the
steps since the last checkpoint).  On a real cluster the same loop wraps
the per-host process under the cluster manager; here it is exercised by
fault-injection tests (tests/test_runtime.py) per DESIGN.md §5.

``StepWatchdog`` is the straggler detector: it tracks a robust step-time
estimate (median + MAD) and flags steps exceeding ``k_mad`` deviations —
the signal a deployment uses to trigger re-dispatch of a slow host's shard
or to exclude a failing node at the next elastic restart.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional


class StepWatchdog:
    def __init__(self, k_mad: float = 6.0, warmup: int = 5):
        self.times: List[float] = []
        self.k_mad = k_mad
        self.warmup = warmup
        self.flagged: List[int] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, *, now: Optional[float] = None) -> bool:
        """Record step duration; returns True when flagged as straggler."""
        dt = (now if now is not None else time.perf_counter()) - self._t0
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        hist = self.times[-100:]
        self.times.append(dt)
        if len(hist) < self.warmup:
            return False
        med = sorted(hist)[len(hist) // 2]
        mad = sorted(abs(t - med) for t in hist)[len(hist) // 2] + 1e-9
        if dt > med + self.k_mad * mad and dt > 1.5 * med:
            self.flagged.append(step)
            return True
        return False


def run_with_restarts(train_fn: Callable[[int], int], *, ckpt_manager,
                      max_restarts: int = 3, logger=print) -> int:
    """Run ``train_fn(start_step) -> final_step`` with crash recovery.

    ``train_fn`` must checkpoint through ``ckpt_manager`` and be resumable
    from any committed step.  Returns the final step reached.
    """
    restarts = 0
    while True:
        start = (ckpt_manager.latest_step() or 0)
        try:
            return train_fn(start)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any step failure triggers recovery
            restarts += 1
            logger(f"[failures] step crashed ({type(e).__name__}: {e}); "
                   f"restart {restarts}/{max_restarts} from step "
                   f"{ckpt_manager.latest_step() or 0}")
            if restarts > max_restarts:
                raise
