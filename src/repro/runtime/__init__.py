from .checkpoint import CheckpointManager            # noqa: F401
from .failures import StepWatchdog, run_with_restarts  # noqa: F401
