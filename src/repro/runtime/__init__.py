from .checkpoint import CheckpointManager            # noqa: F401
from .elastic import (RescalePlan, SortRescalePlan, plan_rescale,  # noqa: F401
                      plan_sort_rescale)
from .failures import (FaultPolicy, StepWatchdog,    # noqa: F401
                       flag_stragglers, run_with_restarts)
