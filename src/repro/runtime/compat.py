"""Version portability shims for the JAX SPMD API.

The repo must run unmodified across JAX releases whose ``shard_map`` moved
(``jax.experimental.shard_map.shard_map`` → ``jax.shard_map``) and whose
replication-check kwarg was renamed (``check_rep`` → ``check_vma``).  Every
call site in the repo goes through :func:`shard_map` below instead of
duplicating try/except import blocks.

Only the subset of the shard_map API the repo uses is exposed: ``mesh``,
``in_specs``, ``out_specs`` and the replication check (named ``check`` here,
translated to whatever the installed JAX calls it).
"""
from __future__ import annotations

import inspect

import jax

try:  # newer JAX exposes shard_map at top level
    _shard_map_impl = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # pragma: no cover - depends on installed version
    from jax.experimental.shard_map import shard_map as _shard_map_impl  # type: ignore

# The replication-check kwarg was renamed check_rep → check_vma; detect what
# the installed implementation accepts so both pins work from one call site.
_params = inspect.signature(_shard_map_impl).parameters
if "check_vma" in _params:
    _CHECK_KWARG = "check_vma"
elif "check_rep" in _params:
    _CHECK_KWARG = "check_rep"
else:  # pragma: no cover - future JAX dropped the kwarg entirely
    _CHECK_KWARG = None


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Portable ``shard_map``: maps ``check`` onto check_vma/check_rep.

    The repo's collective bodies produce un-replicated outputs by design
    (per-PE shards), so ``check`` defaults to off — matching the historical
    ``check_vma=False`` call sites.
    """
    kw = {} if _CHECK_KWARG is None else {_CHECK_KWARG: check}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)
