"""Elastic scaling: plan and execute a topology change at restart time.

The flow on a real cluster: the scheduler grants a different chip count →
the launcher rebuilds the mesh (`plan_rescale`), re-derives the sharding
rules (they reference axis *names* only — dist/sharding.py), and restores
the latest checkpoint onto the new topology (`CheckpointManager.restore`
with the new shardings).  The data pipeline is step-deterministic, so the
batch stream continues exactly where it left off.

Constraints encoded here:
  * global batch must stay divisible by the new data extent (or the plan
    reports the required gradient-accumulation factor);
  * TP-sharded dims must divide the new model extent — the planner shrinks
    the model axis until they do;
  * pod axis absorbs whole-pod growth.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: Dict[str, int]
    new_shape: Dict[str, int]
    grad_accum: int                 # steps to accumulate if batch ∤ data
    notes: Tuple[str, ...]

    @property
    def n_chips(self) -> int:
        return int(np.prod(list(self.new_shape.values())))


def plan_rescale(old_shape: Dict[str, int], n_chips: int, cfg,
                 global_batch: int) -> RescalePlan:
    """Choose a (pod, data, model) factorization of ``n_chips``.

    Keeps the model extent as close to the old one as the architecture's
    shardable dims allow, puts the rest in (pod ×) data.
    """
    notes = []
    model_old = old_shape.get("model", 1)
    # largest model extent ≤ old that divides n_chips and the arch dims
    divisors = [m for m in range(min(model_old, n_chips), 0, -1)
                if n_chips % m == 0 and _model_divides(cfg, m)]
    model = divisors[0] if divisors else 1
    if model != model_old:
        notes.append(f"model axis {model_old}→{model} "
                     f"(arch dims / chip count)")
    rest = n_chips // model
    pod = old_shape.get("pod", 1)
    if rest % pod != 0:
        pod = 1
        notes.append("pod axis collapsed to 1")
    data = rest // pod
    accum = 1
    unit = pod * data
    if global_batch % unit != 0:
        # smallest accum with global_batch % (unit·accum) == 0; when the
        # data extent itself does not divide the batch no such accum
        # exists, so pad the batch up to the next multiple of unit
        # (per-chip microbatch of 1, effective batch unit·accum).
        accum = next((a for a in range(1, max(1, global_batch // unit) + 1)
                      if global_batch % (unit * a) == 0), None)
        if accum is None:
            accum = -(-global_batch // unit)       # ceil: pad, never shrink
            notes.append(f"grad accumulation ×{accum} (batch {global_batch} "
                         f"∤ data extent {unit}; padded to {unit * accum})")
        else:
            notes.append(f"grad accumulation ×{accum} (batch {global_batch} "
                         f"∤ data extent {unit})")
    new = {"data": data, "model": model}
    if pod > 1:
        new = {"pod": pod, **new}
    return RescalePlan(dict(old_shape), new, accum, tuple(notes))


@dataclasses.dataclass(frozen=True)
class SortRescalePlan:
    """Topology change for a sorting mesh after PE failures.

    ``p_new`` is the largest power of two ≤ the survivor count — the
    hypercube layout every sorting algorithm assumes (a p = 1024 sort that
    loses one PE restarts at p = 512, where ``select_algorithm`` may pick
    a different regime).  ``mesh_shape`` is the re-derived (outer, inner)
    nested factorization when the old mesh was hierarchical: the inner
    (intra-host) extent is preserved while it still fits, the outer axis
    absorbs the shrink — axis *names* are unchanged, so the sharding rules
    and ``sort_mesh(..., exclude=failed)`` re-derive the device mesh
    without touching algorithm code.
    """

    p_old: int
    failed: Tuple[int, ...]
    p_new: int
    mesh_shape: Optional[Tuple[int, int]]
    notes: Tuple[str, ...]

    @property
    def survivors(self) -> int:
        return self.p_old - len(self.failed)


def plan_sort_rescale(p_old: int, failed,
                      mesh_shape: Optional[Tuple[int, int]] = None
                      ) -> SortRescalePlan:
    """Plan the sort-mesh topology after excluding ``failed`` PE ranks.

    The sorting analogue of :func:`plan_rescale`: given the old axis
    extent (or nested ``mesh_shape``) and the flat ranks of the
    dead/straggling PEs, derive the reduced power-of-two extent the sort
    re-runs at.  Raises ``ValueError`` when no usable topology survives.
    """
    failed = tuple(sorted({int(f) for f in failed if 0 <= int(f) < p_old}))
    alive = p_old - len(failed)
    if alive < 1:
        raise ValueError(f"no surviving PEs (p={p_old}, failed={failed})")
    p_new = 1 << (alive.bit_length() - 1)          # largest pow2 ≤ alive
    notes = []
    if p_new != alive:
        notes.append(f"{alive} survivors rounded down to p={p_new} "
                     f"(hypercube layout)")
    new_shape = None
    if mesh_shape is not None:
        p_o, p_i = (int(v) for v in mesh_shape)
        p_i_new = min(p_i, p_new)
        p_o_new = p_new // p_i_new
        new_shape = (p_o_new, p_i_new)
        if new_shape != (p_o, p_i):
            notes.append(f"nested mesh {(p_o, p_i)} → {new_shape} "
                         f"(inner axis preserved while it fits)")
    return SortRescalePlan(int(p_old), failed, int(p_new), new_shape,
                           tuple(notes))


def _model_divides(cfg, m: int) -> bool:
    dims = [cfg.d_ff, cfg.n_heads * cfg.head_dim]
    if cfg.n_experts:
        dims.append(cfg.n_experts * cfg.d_ff)
    return all(d % m == 0 for d in dims if d)


def rescale_state(state, state_like, cfg, new_mesh, ckpt_manager,
                  step: Optional[int] = None):
    """Restore ``state_like``-shaped state from the checkpoint onto
    ``new_mesh`` with re-derived shardings (the elastic restart path)."""
    from repro.dist.sharding import make_shardings
    import jax

    shards = make_shardings(jax.eval_shape(lambda: state_like), cfg, new_mesh)
    return ckpt_manager.restore(state_like, step=step, shardings=shards)
