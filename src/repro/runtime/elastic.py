"""Elastic scaling: plan and execute a topology change at restart time.

The flow on a real cluster: the scheduler grants a different chip count →
the launcher rebuilds the mesh (`plan_rescale`), re-derives the sharding
rules (they reference axis *names* only — dist/sharding.py), and restores
the latest checkpoint onto the new topology (`CheckpointManager.restore`
with the new shardings).  The data pipeline is step-deterministic, so the
batch stream continues exactly where it left off.

Constraints encoded here:
  * global batch must stay divisible by the new data extent (or the plan
    reports the required gradient-accumulation factor);
  * TP-sharded dims must divide the new model extent — the planner shrinks
    the model axis until they do;
  * pod axis absorbs whole-pod growth.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: Dict[str, int]
    new_shape: Dict[str, int]
    grad_accum: int                 # steps to accumulate if batch ∤ data
    notes: Tuple[str, ...]

    @property
    def n_chips(self) -> int:
        return int(np.prod(list(self.new_shape.values())))


def plan_rescale(old_shape: Dict[str, int], n_chips: int, cfg,
                 global_batch: int) -> RescalePlan:
    """Choose a (pod, data, model) factorization of ``n_chips``.

    Keeps the model extent as close to the old one as the architecture's
    shardable dims allow, puts the rest in (pod ×) data.
    """
    notes = []
    model_old = old_shape.get("model", 1)
    # largest model extent ≤ old that divides n_chips and the arch dims
    divisors = [m for m in range(min(model_old, n_chips), 0, -1)
                if n_chips % m == 0 and _model_divides(cfg, m)]
    model = divisors[0] if divisors else 1
    if model != model_old:
        notes.append(f"model axis {model_old}→{model} "
                     f"(arch dims / chip count)")
    rest = n_chips // model
    pod = old_shape.get("pod", 1)
    if rest % pod != 0:
        pod = 1
        notes.append("pod axis collapsed to 1")
    data = rest // pod
    accum = 1
    if global_batch % (pod * data) != 0:
        accum = int(np.ceil((pod * data) / max(global_batch, 1)))
        notes.append(f"grad accumulation ×{accum} (batch {global_batch} "
                     f"∤ data extent {pod * data})")
    new = {"data": data, "model": model}
    if pod > 1:
        new = {"pod": pod, **new}
    return RescalePlan(dict(old_shape), new, accum, tuple(notes))


def _model_divides(cfg, m: int) -> bool:
    dims = [cfg.d_ff, cfg.n_heads * cfg.head_dim]
    if cfg.n_experts:
        dims.append(cfg.n_experts * cfg.d_ff)
    return all(d % m == 0 for d in dims if d)


def rescale_state(state, state_like, cfg, new_mesh, ckpt_manager,
                  step: Optional[int] = None):
    """Restore ``state_like``-shaped state from the checkpoint onto
    ``new_mesh`` with re-derived shardings (the elastic restart path)."""
    from repro.dist.sharding import make_shardings
    import jax

    shards = make_shardings(jax.eval_shape(lambda: state_like), cfg, new_mesh)
    return ckpt_manager.restore(state_like, step=step, shardings=shards)
