"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step:  <dir>/step_000123/
    manifest.json       — step, pytree structure, per-leaf shape/dtype/crc,
                          mesh axes the state was sharded over
    leaf_<k>.npy        — one file per pytree leaf (full array; on a real
                          multi-host deployment each host writes its shard —
                          single-process here, noted in DESIGN.md)
    _COMMITTED          — written last; restore ignores dirs without it
                          (atomicity under crash-during-save)

Elastic restore: arrays are loaded in full and re-placed with
``jax.device_put`` under the *target* mesh's shardings, so a checkpoint
written on (data=4, model=2) restores onto (data=2, model=4) or any other
topology — the sharding rules only reference axis names (dist/sharding.py).

Saves run on a background thread (``save_async``) double-buffered through a
host copy, overlapping serialization with the next training steps.
"""
from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, *, blocking: bool = True):
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if blocking:
            self._write(step, host_state)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()

    def save_async(self, step: int, state: Any):
        self.save(step, state, blocking=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state):
        tmp = self.dir / f".tmp_step_{step:09d}"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        import ml_dtypes
        leaves, treedef = jax.tree.flatten(host_state)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for k, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            logical = str(arr.dtype)
            if arr.dtype == ml_dtypes.bfloat16:   # not np.save-able natively
                arr = arr.view(np.uint16)
                logical = "bfloat16"
            np.save(tmp / f"leaf_{k}.npy", arr)
            manifest["leaves"].append({
                "shape": list(arr.shape), "dtype": logical,
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "_COMMITTED").touch()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in self.dir.glob("step_*"):
            if (d / "_COMMITTED").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``state_like``; if ``shardings`` is
        given (pytree of NamedSharding), device_put accordingly (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves_like, treedef = jax.tree.flatten(state_like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError("checkpoint/state structure mismatch: "
                             f"{len(manifest['leaves'])} vs {len(leaves_like)}")
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves_like))
        import ml_dtypes
        for k, (meta, like, shd) in enumerate(
                zip(manifest["leaves"], leaves_like, shard_leaves)):
            arr = np.load(d / f"leaf_{k}.npy")
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc"]:
                raise IOError(f"checkpoint corruption in leaf_{k}")
            if meta["dtype"] == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(like.shape):
                raise ValueError(f"leaf_{k} shape {arr.shape} != {like.shape}")
            out.append(jax.device_put(arr, shd) if shd is not None
                       else jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)
