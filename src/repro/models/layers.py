"""Shared layers: RMSNorm, RoPE, MLPs, embeddings, norms-with-sharding."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def init_rms(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


# --- RoPE ------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))            # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- MLPs ------------------------------------------------------------------


def init_mlp(key, d: int, f: int, gated: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d))
    s_out = float(1.0 / np.sqrt(f))
    p = {"up": jax.random.normal(k1, (d, f), dtype) * s_in,
         "down": jax.random.normal(k2, (f, d), dtype) * s_out}
    if gated:
        p["gate"] = jax.random.normal(k3, (d, f), dtype) * s_in
    return p


def mlp(x: jax.Array, p: dict, act: str) -> jax.Array:
    h = x @ p["up"]
    if act == "silu":                        # gated SiLU (llama family)
        h = jax.nn.silu(x @ p["gate"]) * h
    elif act == "relu2":                     # squared ReLU (nemotron)
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ p["down"]


# --- Embedding / head ------------------------------------------------------


def init_embed(key, vocab: int, d: int, dtype) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean next-token CE; logits (..., V) f32-accumulated, labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
