"""State-space blocks: Mamba2 (SSD, chunked) and RWKV6 (Finch, chunked WKV).

Both use the chunked formulation: intra-chunk contributions are computed
with dense (MXU-friendly) matmuls under a decay mask; inter-chunk state is
carried by a scan over chunks.  Decode steps update an explicit recurrent
state — these are the architectures for which ``long_500k`` runs (O(1)
state instead of a 500k KV cache).

Numerics: decays are accumulated in log space per chunk, so the largest
exponent inside a chunk is bounded by chunk_len·max|log w| — safe in f32
for the chunk sizes used here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import rms_norm

CHUNK = 128


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def init_mamba2(key, d: int, n_heads: int, d_state: int, dtype,
                expand: int = 2, d_conv: int = 4) -> dict:
    di = expand * d
    hd = di // n_heads
    ks = jax.random.split(key, 6)
    s = float(1.0 / np.sqrt(d))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * d_state + n_heads),
                                     dtype) * s,
        "conv": jax.random.normal(ks[1], (d_conv, di + 2 * d_state), dtype) * 0.1,
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * float(1.0 / np.sqrt(di)),
    }


class MambaState(NamedTuple):
    ssm: jax.Array          # (B, H, hd, N) f32
    conv: jax.Array         # (B, d_conv-1, conv_dim)


def _mamba_split(z, di, d_state, H):
    x, zgate, B, C, dt = jnp.split(
        z, [di, 2 * di, 2 * di + d_state, 2 * di + 2 * d_state], axis=-1)
    return x, zgate, B, C, dt


def mamba2(xin: jax.Array, p: dict, cfg) -> jax.Array:
    """Train/prefill path, chunked SSD.  xin: (B,S,D)."""
    Bsz, S, D = xin.shape
    H = cfg.ssm_heads
    N = cfg.ssm_state
    di = 2 * D
    hd = di // H
    z = xin @ p["in_proj"]
    x, zgate, Bm, Cm, dt = _mamba_split(z, di, N, H)
    # causal depthwise conv over (x, B, C)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    k = p["conv"].shape[0]
    pad = jnp.zeros((Bsz, k - 1, xbc.shape[-1]), xbc.dtype)
    xbc_p = jnp.concatenate([pad, xbc], axis=1)
    conv = sum(xbc_p[:, i:i + S] * p["conv"][i][None, None] for i in range(k))
    conv = jax.nn.silu(conv)
    x, Bm, Cm = jnp.split(conv, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    xh = x.reshape(Bsz, S, H, hd)
    y, _ = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=min(CHUNK, S))
    y = y + xh * p["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(zgate.astype(jnp.float32)
                                             ).astype(y.dtype)
    return y @ p["out_proj"]


def _ssd_chunked(x, dt, A, B, C, chunk: int = CHUNK):
    """SSD: y_t = C_t · h_t,  h_t = exp(A·dt_t)·h_{t-1} + dt_t·B_t x_t.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); B,C: (B,S,N) (single group).
    Returns (y (B,S,H,P), final state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = B.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = C.reshape(Bsz, nc, chunk, N).astype(jnp.float32)

    da = dtc * A[None, None, None, :]                  # (B,nc,c,H) ≤ 0
    cum = jnp.cumsum(da, axis=2)                       # inclusive
    seg_sum = cum[:, :, -1:, :]                        # (B,nc,1,H)

    xdt = (xc.astype(jnp.float32) * dtc[..., None])
    # intra-chunk: y_i += Σ_{j≤i} C_i·B_j · exp(cum_i - cum_j) · dt_j x_j
    scores = jnp.einsum("bnif,bnjf->bnij", Cc, Bc)     # (B,nc,c,c)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,i,j,H)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    w = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", scores, w, xdt)

    # chunk states: G_n = Σ_j exp(seg_sum - cum_j) · B_j ⊗ dt_j x_j
    wj = jnp.exp(seg_sum - cum)                        # (B,nc,c,H)
    G = jnp.einsum("bnjf,bnjh,bnjhp->bnhpf", Bc, wj, xdt)   # (B,nc,H,P,N)

    # carry states across chunks:  h_n = exp(seg_sum_n)·h_{n-1} + G_n
    seg = jnp.exp(seg_sum[:, :, 0, :])                 # (B,nc,H)

    def step(h, inp):
        g, sg = inp
        h = h * sg[:, :, None, None] + g
        return h, h

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, hs = jax.lax.scan(step, h0,
                         (G.transpose(1, 0, 2, 3, 4), seg.transpose(1, 0, 2)))
    hs = hs.transpose(1, 0, 2, 3, 4)                   # (B,nc,H,P,N) inclusive
    h_prev = jnp.concatenate([h0[:, None], hs[:, :-1]], axis=1)
    # inter-chunk: y_i += C_i · exp(cum_i) · h_prev
    y_inter = jnp.einsum("bnif,bnih,bnhpf->bnihp",
                         Cc, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P).astype(x.dtype)
    return y, hs[:, -1]


def mamba2_decode(xin: jax.Array, p: dict, cfg, state: MambaState):
    """One-token decode.  xin: (B,1,D)."""
    Bsz, _, D = xin.shape
    H, N = cfg.ssm_heads, cfg.ssm_state
    di = 2 * D
    hd = di // H
    z = xin[:, 0] @ p["in_proj"]
    x, zgate, Bm, Cm, dt = _mamba_split(z, di, N, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)        # (B, convdim)
    k = p["conv"].shape[0]
    hist = jnp.concatenate([state.conv, xbc[:, None]], axis=1)  # (B,k,convdim)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv"])
    conv = jax.nn.silu(conv)
    x, Bm, Cm = jnp.split(conv, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(Bsz, H, hd).astype(jnp.float32)
    decay = jnp.exp(dt * A[None])                       # (B,H)
    upd = jnp.einsum("bhp,bf,bh->bhpf", xh, Bm.astype(jnp.float32), dt)
    ssm = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bf,bhpf->bhp", Cm.astype(jnp.float32), ssm)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, di)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(zgate.astype(jnp.float32)
                                             ).astype(y.dtype)
    out = (y.astype(xin.dtype) @ p["out_proj"])[:, None]
    return out, MambaState(ssm=ssm, conv=hist[:, 1:])


# ===========================================================================
# RWKV6 (Finch): data-dependent per-channel decay
# ===========================================================================


def init_rwkv6(key, d: int, n_heads: int, dtype, lora: int = 64) -> dict:
    ks = jax.random.split(key, 10)
    s = float(1.0 / np.sqrt(d))
    hd = d // n_heads
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),   # token-shift mix r,k,v,w,g
        "wr": jax.random.normal(ks[0], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[3], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[4], (d, d), dtype) * s,
        # data-dependent decay lora: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": jax.random.normal(ks[5], (d, lora), dtype) * s,
        "wB": jax.random.normal(ks[6], (lora, d), dtype) * float(1.0 / np.sqrt(lora)),
        "u": jnp.zeros((n_heads, hd), jnp.float32),   # bonus for current token
        "ln_x": jnp.ones((d,), jnp.float32),
    }


class RWKVState(NamedTuple):
    wkv: jax.Array          # (B, H, hd_k, hd_v) f32
    last: jax.Array         # (B, D) previous token features


def _rwkv_proj(x, xprev, p):
    """Token-shift mixing + projections.  x: (B,S,D); xprev: shifted x."""
    mu = p["mu"].astype(x.dtype)
    xs = [xprev + mu[i][None, None] * (x - xprev) for i in range(5)]
    r = xs[0] @ p["wr"]
    k = xs[1] @ p["wk"]
    v = xs[2] @ p["wv"]
    lw = p["w0"] + jnp.tanh(xs[3].astype(jnp.float32) @ p["wA"].astype(jnp.float32)) \
        @ p["wB"].astype(jnp.float32)
    logw = -jnp.exp(lw)                                 # log decay ≤ 0, (B,S,D)
    g = jax.nn.silu(xs[4] @ p["wg"])
    return r, k, v, logw, g


def rwkv6(xin: jax.Array, p: dict, cfg) -> jax.Array:
    """Chunked WKV.  xin: (B,S,D)."""
    B, S, D = xin.shape
    H = cfg.n_heads
    hd = D // H
    xprev = jnp.concatenate([jnp.zeros_like(xin[:, :1]), xin[:, :-1]], axis=1)
    r, k, v, logw, g = _rwkv_proj(xin, xprev, p)
    rh = r.reshape(B, S, H, hd).astype(jnp.float32)
    kh = k.reshape(B, S, H, hd).astype(jnp.float32)
    vh = v.reshape(B, S, H, hd).astype(jnp.float32)
    lw = logw.reshape(B, S, H, hd)
    y = _wkv_chunked(rh, kh, vh, lw, p["u"], chunk=min(CHUNK, S))
    y = y.reshape(B, S, D)
    y = rms_norm(y.astype(xin.dtype), p["ln_x"]) * g
    return y @ p["wo"]


def _wkv_chunked(r, k, v, lw, u, chunk: int = CHUNK):
    """WKV recurrence, chunked:
       S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ ;  y_t = rᵀ_t (S_{t-1} + diag(u)·k_t v_tᵀ)
    r,k,v: (B,S,H,K);  lw: log decays (B,S,H,K);  u: (H,K)."""
    B, S, H, K = r.shape
    nc = S // chunk
    rc = r.reshape(B, nc, chunk, H, K)
    kc = k.reshape(B, nc, chunk, H, K)
    vc = v.reshape(B, nc, chunk, H, K)
    lwc = lw.reshape(B, nc, chunk, H, K)
    cum = jnp.cumsum(lwc, axis=2)                       # inclusive decay sums
    seg = cum[:, :, -1]                                 # (B,nc,H,K)

    # intra-chunk: y_i = Σ_{j<i} (r_i·exp(cum_{i-1}-cum_j)·k_j) v_j + (r_i·u·k_i) v_i
    cum_ex = cum - lwc                                  # exclusive prefix
    ri = rc * jnp.exp(cum_ex)
    kj = kc * jnp.exp(-cum)
    att = jnp.einsum("bnihk,bnjhk->bnhij", ri, kj)
    mask = jnp.tril(jnp.ones((chunk, chunk)), -1)
    att = att * mask[None, None, None]
    diag = jnp.einsum("bnihk,hk,bnihk->bnih", rc, u, kc)
    y_intra = jnp.einsum("bnhij,bnjhv->bnihv", att, vc) \
        + diag[..., None] * vc

    # chunk state updates: G_n = Σ_j exp(seg - cum_j) k_j ⊗ v_j
    wk = jnp.exp(seg[:, :, None] - cum) * kc            # (B,nc,c,H,K)
    G = jnp.einsum("bnjhk,bnjhv->bnhkv", wk, vc)
    segd = jnp.exp(seg)                                 # (B,nc,H,K)

    def step(Sst, inp):
        g, sd = inp
        new = Sst * sd[..., None] + g
        return new, Sst                                 # emit the *previous*

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    _, Sprev = jax.lax.scan(step, S0, (G.transpose(1, 0, 2, 3, 4),
                                       segd.transpose(1, 0, 2, 3)))
    Sprev = Sprev.transpose(1, 0, 2, 3, 4)              # (B,nc,H,K,V)
    y_inter = jnp.einsum("bnihk,bnhkv->bnihv", rc * jnp.exp(cum_ex), Sprev)
    return (y_intra + y_inter).reshape(B, S, H, K)


def rwkv6_decode(xin: jax.Array, p: dict, cfg, state: RWKVState):
    B, _, D = xin.shape
    H = cfg.n_heads
    hd = D // H
    xprev = state.last[:, None].astype(xin.dtype)
    r, k, v, logw, g = _rwkv_proj(xin, xprev, p)
    rh = r.reshape(B, H, hd).astype(jnp.float32)
    kh = k.reshape(B, H, hd).astype(jnp.float32)
    vh = v.reshape(B, H, hd).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, hd))
    u = p["u"]
    y = jnp.einsum("bhk,bhkv->bhv", rh, state.wkv) \
        + jnp.einsum("bhk,hk,bhk,bhv->bhv", rh, u, kh, vh)
    wkv = state.wkv * w[..., None] + jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = y.reshape(B, D)
    y = rms_norm(y.astype(xin.dtype), p["ln_x"]) * g[:, 0] if g.ndim == 3 else \
        rms_norm(y.astype(xin.dtype), p["ln_x"]) * g
    out = (y @ p["wo"])[:, None]
    return out, RWKVState(wkv=wkv, last=xin[:, 0].astype(jnp.float32))


def init_rwkv_channelmix(key, d: int, f: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {"mu": jnp.full((2, d), 0.5, jnp.float32),
            "wk": jax.random.normal(k1, (d, f), dtype) * float(1.0 / np.sqrt(d)),
            "wv": jax.random.normal(k2, (f, d), dtype) * float(1.0 / np.sqrt(f))}


def rwkv_channelmix(x: jax.Array, xprev: jax.Array, p: dict) -> jax.Array:
    mu = p["mu"].astype(x.dtype)
    xk = xprev + mu[0] * (x - xprev)
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return h @ p["wv"]
