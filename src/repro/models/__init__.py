"""Model substrate: transformer / MoE / SSM / hybrid architectures.

Functional style: params are plain pytrees (dicts of jnp arrays), each
module exposes ``init_*`` and ``apply`` functions.  All dtypes are explicit
(bf16 activations/weights, f32 norms & router logits) — the sorting core
enables jax_enable_x64 and model code must be unaffected by it.
"""
