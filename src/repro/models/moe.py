"""Mixture-of-Experts with **sort-based token dispatch** — the paper's
robust sorting integrated in the training hot path.

Token routing produces n keys drawn from E ≤ 64 distinct values: exactly
the paper's DeterDupl instance.  Dispatch = sort items by expert id with
position tie-breaking (the RAMS/SSSS partition with *exact* splitters —
expert ownership boundaries — so no sampling phase is needed), exchange
with one fused slotted all-to-all, compute, and route back.  Load balance
of the static slots is the tie-breaking property of App. G; overflowed
items are dropped against a capacity factor, exactly like production MoE.

Two parallel layouts (DESIGN.md §5):
  * ``ep``  — experts sharded over the model axis (granite: 32/16): tokens
    are sequence-sharded over the axis and exchanged with the slotted
    all-to-all inside shard_map — the *distributed* sort path;
  * ``tp``  — experts replicated, FFN hidden dim TP-sharded (mixtral:
    8 experts on 16 ranks): grouping happens locally (the same one-hot
    scan the kway kernel implements), GSPMD reduces the down-projection.

``impl="dense"`` keeps the one-hot einsum dispatch as the measurable
baseline (benchmarks/moe_dispatch.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import rms_norm


def init_moe(key, d: int, f: int, n_experts: int, dtype) -> dict:
    kr, ku, kg, kd = jax.random.split(key, 4)
    s_in = float(1.0 / np.sqrt(d))
    s_out = float(1.0 / np.sqrt(f))
    return {
        "router": jax.random.normal(kr, (d, n_experts), jnp.float32) * s_in,
        "up": jax.random.normal(ku, (n_experts, d, f), dtype) * s_in,
        "gate": jax.random.normal(kg, (n_experts, d, f), dtype) * s_in,
        "down": jax.random.normal(kd, (n_experts, f, d), dtype) * s_out,
    }


def _router(x, w, top_k: int):
    """x: (..., D) → (probs (..., k) f32, ids (..., k) i32, aux loss)."""
    logits = (x.astype(jnp.float32) @ w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch): E · Σ_e f_e · p_e
    E = w.shape[1]
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    fr = jnp.mean((top_i[..., None] == jnp.arange(E)).reshape(-1, E)
                  .astype(jnp.float32), axis=0)
    aux = E * jnp.sum(me * fr)
    return top_p, top_i.astype(jnp.int32), aux


def _expert_ffn(buf, up, gate, down):
    """buf: (E, C, D); weights (E, D, F)/(E, F, D)."""
    h = jnp.einsum("ecd,edf->ecf", buf, up)
    g = jnp.einsum("ecd,edf->ecf", buf, gate)
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, down)


def _group_by_expert(eids, n_experts: int, capacity: int):
    """One-hot scan grouping (the kway-kernel operation, jnp form).

    eids: (N,) int32 → (slot (N,), kept (N,) bool).  Slot is the position
    of the item within its expert's capacity buffer.
    """
    onehot = eids[:, None] == jnp.arange(n_experts, dtype=jnp.int32)[None, :]
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.sum(jnp.where(onehot, pos, 0), axis=1)
    kept = slot < capacity
    return slot, kept


def moe_local(x, p, cfg, *, capacity_factor: float = 2.0):
    """TP layout: group locally per batch row, einsum over all experts."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    w, ids, aux = _router(x, p["router"], k)          # (B,S,k)
    N = S * k
    cap = int(capacity_factor * N / E) + 1
    ids2 = ids.reshape(B, N)
    w2 = w.reshape(B, N)

    slot, kept = jax.vmap(lambda e: _group_by_expert(e, E, cap))(ids2)
    # scatter tokens into (B, E, cap, D)
    xrep = jnp.repeat(x, k, axis=1).reshape(B, N, D)   # item i ← token i//k
    flat = jnp.where(kept, ids2 * cap + slot, E * cap)
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype)
    buf = jax.vmap(lambda b, f, v: b.at[f].set(v))(buf, flat, xrep)
    buf = buf[:, :-1].reshape(B * E, cap, D).reshape(B, E, cap, D)
    out = jax.vmap(lambda bb: _expert_ffn(bb, p["up"], p["gate"], p["down"]))(buf)
    out = out.reshape(B, E * cap, D)
    # gather back
    gathered = jax.vmap(lambda o, f: o[jnp.clip(f, 0, E * cap - 1)])(out, flat)
    gathered = jnp.where(kept[..., None], gathered, 0.0)
    y = jnp.sum((gathered.reshape(B, S, k, D)
                 * w.astype(x.dtype)[..., None]), axis=2)
    return y, aux


def moe_dense(x, p, cfg):
    """Dense one-hot dispatch baseline: computes every expert for every
    token via masked combine — simple, robust, E× the FLOPs."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    w, ids, aux = _router(x, p["router"], k)
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)          # (B,S,k,E)
    cw = jnp.sum(onehot * w[..., None], axis=2)                 # (B,S,E)
    h = jnp.einsum("bsd,edf->bsef", x, p["up"])
    g = jnp.einsum("bsd,edf->bsef", x, p["gate"])
    h = jax.nn.silu(g) * h
    y = jnp.einsum("bsef,efd->bsed", h, p["down"])
    y = jnp.sum(y * cw[..., None].astype(x.dtype), axis=2)
    return y, aux


def _ep_dispatch_body(cfg, model_axis: str, ep: int,
                      capacity_factor: float, slot_factor: float):
    """The per-PE EP dispatch body, shared by ``moe_ep_shardmap`` (real
    2-D device mesh) and ``moe_ep_sim`` (emulated (d, ep) mesh).

    Every collective inside names ``model_axis`` only, so the dispatch
    sorts/exchanges within the ep-sized expert-parallel subgroup of
    whatever mesh surrounds it — the data axis never communicates.
    """
    from repro.core import comm
    from repro.core.hypercube import _alltoall_route
    from repro.core.types import SortShard

    E, k = cfg.n_experts, cfg.top_k
    e_per = E // ep
    assert e_per >= 1

    def body(x_blk, router, up, gate, down):
        me = comm.axis_index(model_axis)
        B, S_loc, D = x_blk.shape
        T = B * S_loc
        xt = x_blk.reshape(T, D)
        w, ids, aux = _router(xt, router, k)                    # (T,k)
        N = T * k
        eids = ids.reshape(N)
        feat = jnp.repeat(xt, k, axis=0)                        # (N,D)
        src = jnp.arange(N, dtype=jnp.uint32) // np.uint32(k)
        wgt = w.reshape(N).astype(jnp.float32)

        shard = SortShard(
            keys=eids.astype(jnp.uint32),
            vals={"feat": feat, "src": src, "w": wgt,
                  "org": jnp.full((N,), me.astype(jnp.uint32))},
            count=jnp.int32(N))
        dest = eids // e_per                                    # exact splitters
        slot_cap = int(slot_factor * N / ep) + 8
        recv, drop1 = _alltoall_route(shard, dest.astype(jnp.int32),
                                      model_axis, ep, slot_cap)
        # group received items by local expert (the SSSS partition step)
        M = recv.capacity
        leid = (recv.keys.astype(jnp.int32) - me.astype(jnp.int32) * e_per)
        leid = jnp.where(recv.valid_mask(), jnp.clip(leid, 0, e_per - 1), e_per)
        cap_e = int(capacity_factor * k * T / E) + 8
        slot, kept = _group_by_expert(leid, e_per, cap_e)
        kept &= recv.valid_mask()
        flat = jnp.where(kept, leid * cap_e + slot, e_per * cap_e)
        buf = jnp.zeros((e_per * cap_e + 1, D), x_blk.dtype)
        buf = buf.at[flat].set(jnp.where(kept[:, None], recv.vals["feat"], 0))
        buf = buf[:-1].reshape(e_per, cap_e, D)
        out = _expert_ffn(buf, up, gate, down)                  # (e_per,cap,D)
        out = out.reshape(e_per * cap_e, D)
        yitem = jnp.where(kept[:, None],
                          out[jnp.clip(flat, 0, e_per * cap_e - 1)], 0)
        # route items back to their origin rank
        back = SortShard(keys=recv.keys,
                         vals={"feat": yitem, "src": recv.vals["src"],
                               "w": recv.vals["w"]},
                         count=recv.count)
        back_dest = jnp.where(recv.valid_mask(),
                              recv.vals["org"].astype(jnp.int32), ep)
        ret, drop2 = _alltoall_route(back, back_dest, model_axis, ep, slot_cap)
        y = jnp.zeros((T + 1, D), jnp.float32)
        rsrc = jnp.where(ret.valid_mask(), ret.vals["src"].astype(jnp.int32), T)
        y = y.at[rsrc].add(ret.vals["feat"].astype(jnp.float32)
                           * ret.vals["w"][:, None])
        y = y[:-1].astype(x_blk.dtype).reshape(B, S_loc, D)
        return y, aux[None], (drop1 + drop2)[None]

    return body


def moe_ep_shardmap(x, p, cfg, mesh, *, data_axes, model_axis="model",
                    capacity_factor: float = 2.0, slot_factor: float = 2.0):
    """EP layout: distributed sort-based dispatch over ``model_axis``.

    x: (B, S, D) with batch sharded over data_axes; inside the shard_map the
    sequence is additionally split over the model axis, items are exchanged
    by expert ownership with the paper's slotted all-to-all, computed, and
    routed back (vals carry the bf16 feature vectors as 2-D payload).
    ``mesh`` may carry any number of data axes — the dispatch collectives
    are relative to ``model_axis``, so each (data...)-slice's ep-subgroup
    sorts independently.
    """
    from jax.sharding import PartitionSpec as P
    from repro.runtime.compat import shard_map

    ep = mesh.shape[model_axis]
    body = _ep_dispatch_body(cfg, model_axis, ep, capacity_factor,
                             slot_factor)

    dp = P(data_axes, model_axis, None)
    y, aux, drops = shard_map(
        body, mesh=mesh,
        in_specs=(dp, P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(dp, P(model_axis), P(model_axis)),
    )(x, p["router"], p["up"], p["gate"], p["down"])
    return y, jnp.mean(aux)


def moe_ep_sim(x, p, cfg, *, d: int = 1, ep: Optional[int] = None,
               model_axis: str = "expert",
               capacity_factor: float = 2.0, slot_factor: float = 2.0):
    """EP dispatch on the **sim backend** over an emulated (d, ep) mesh.

    Runs the exact ``moe_ep_shardmap`` body with
    ``comm.sim_map(..., mesh=(d, ep))``: the batch splits into d data-axis
    rows, the sequence into ep expert-parallel blocks, and each row's
    dispatch sorts within its own ep-sized subgroup — the multi-tenant
    layout (many independent MoE replicas per host) without needing
    d·ep physical devices.  Returns (y, aux) like the shard_map path.
    """
    from repro.core import comm

    B, S, D = x.shape
    E = cfg.n_experts
    ep = ep or E
    if B % d or S % ep or E % ep:
        raise ValueError(f"B={B} S={S} E={E} not divisible by (d={d}, "
                         f"ep={ep})")
    e_per = E // ep
    body = _ep_dispatch_body(cfg, model_axis, ep, capacity_factor,
                             slot_factor)
    # (B, S, D) → (d, ep, B/d, S/ep, D): batch over data rows, sequence
    # over expert-parallel blocks — the sim image of the shard_map specs
    # P(data_axes, model_axis, None).
    xb = x.reshape(d, B // d, ep, S // ep, D)
    xb = jnp.moveaxis(xb, 2, 1)

    def tile(w, split_experts):
        if split_experts:                  # (E, ...) → per-PE (e_per, ...)
            w = w.reshape((ep, e_per) + w.shape[1:])
        else:                              # replicated across the mesh
            w = jnp.broadcast_to(w[None], (ep,) + w.shape)
        return jnp.broadcast_to(w[None], (d,) + w.shape)

    run = comm.sim_map(body, model_axis, ep, mesh=(d, ep), data_axis="data")
    y, aux, drops = run(xb, tile(p["router"], False), tile(p["up"], True),
                        tile(p["gate"], True), tile(p["down"], True))
    y = jnp.moveaxis(y, 1, 2).reshape(B, S, D)   # (d, ep, b, s, D) → (B, S, D)
    return y, jnp.mean(aux)


def moe_tp_shardmap(x, p, cfg, mesh, *, data_axes,
                    capacity_factor: float = 2.0):
    """TP layout, §Perf-optimized: group locally, run the F-sharded experts
    inside shard_map and psum the *combined tokens* (B,S,D) instead of
    letting GSPMD all-reduce the (B,E,cap,D) capacity buffer — ~cf·E/k ×
    less collective volume (the mixtral hillclimb, EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P
    from repro.core import comm
    from repro.runtime.compat import shard_map

    E, k = cfg.n_experts, cfg.top_k
    dp = P(data_axes, None, None)

    def body(x_blk, router, up, gate, down):
        y, aux = moe_local(x_blk, {"router": router, "up": up, "gate": gate,
                                   "down": down}, cfg,
                           capacity_factor=capacity_factor)
        y = comm.psum(y, "model")
        return y, aux[None]

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(dp, P(), P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=(dp, P("model")),
    )(x, p["router"], p["up"], p["gate"], p["down"])
    return y, jnp.mean(aux)


def moe_apply(x, p, cfg, mesh=None, *, data_axes=("data",),
              impl: Optional[str] = None):
    impl = impl or cfg.moe_impl
    if impl == "dense":
        return moe_dense(x, p, cfg)
    if (impl == "sort" and mesh is not None and "model" in mesh.shape
            and cfg.n_experts % mesh.shape["model"] == 0
            and x.shape[1] % mesh.shape["model"] == 0):   # decode: S=1 →
        return moe_ep_shardmap(x, p, cfg, mesh, data_axes=data_axes)
    if (impl == "sort" and getattr(cfg, "moe_tp_fused", False)
            and mesh is not None and "model" in mesh.shape):
        return moe_tp_shardmap(x, p, cfg, mesh, data_axes=data_axes)
    return moe_local(x, p, cfg)                           # local grouping
