"""GQA attention: flash-style chunked prefill (online softmax), sliding
window, qk-norm, and single-token decode against a KV cache.

The chunked path is the memory-hygiene requirement for the 32k prefill
shapes: a (B,H,S,S) score tensor would be ~TBs; scanning KV blocks with a
running (max, denominator) keeps activations at O(S·blk) per head.
``window`` limits attention to the last W positions (mixtral SWA); the
baseline computes all causal blocks and masks — block *skipping* for SWA is
a §Perf optimization (banded=True).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, init_rms, rms_norm

NEG_INF = -1e30


def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(d))
    p = {"wq": jax.random.normal(kq, (d, n_heads * head_dim), dtype) * s,
         "wk": jax.random.normal(kk, (d, n_kv * head_dim), dtype) * s,
         "wv": jax.random.normal(kv, (d, n_kv * head_dim), dtype) * s,
         "wo": jax.random.normal(ko, (n_heads * head_dim, d), dtype)
               * float(1.0 / np.sqrt(n_heads * head_dim))}
    if qk_norm:
        p["q_norm"] = init_rms(head_dim)
        p["k_norm"] = init_rms(head_dim)
    return p


def _qkv(x, p, cfg, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention(x: jax.Array, p: dict, cfg, *, block: int = 1024,
              banded: Optional[bool] = None, mesh=None) -> jax.Array:
    """Causal self-attention for training/prefill.  x: (B,S,D)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _qkv(x, p, cfg, positions)
    window = cfg.sliding_window
    if banded is None:
        banded = bool(window) and getattr(cfg, "swa_banded", False)

    if getattr(cfg, "attn_context_parallel", False) and mesh is not None \
            and S > block:
        out = _attend_cp(q, k, v, H // KV, window, block, banded, mesh)
    elif S <= block:
        out = _attend_dense(q, k, v, H // KV, window)
    else:
        out = _attend_chunked(q, k, v, H // KV, window, block, banded)
    return out.reshape(B, S, H * hd) @ p["wo"]


def _attend_cp(q, k, v, n_rep, window, block, banded, mesh):
    """Context-parallel flash attention (§Perf): the query-block dim shards
    over the model axis (works for ANY head count — the fix for archs whose
    heads don't divide the TP degree, where GSPMD otherwise head-dim-shards
    the contraction and all-reduces every score block); K/V replicate over
    model; one scan over KV blocks with a fully vectorized query dim.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import data_axes_of
    B, S, H, hd = q.shape
    nq = S // block
    dp = data_axes_of(mesh) if B % max(
        1, int(np.prod([mesh.shape[a] for a in data_axes_of(mesh)]))) == 0 \
        else ()
    msize = mesh.shape.get("model", 1)
    cp = "model" if nq % msize == 0 else None
    wsc = jax.lax.with_sharding_constraint
    qb = q.reshape(B, nq, block, H, hd)
    qb = wsc(qb, NamedSharding(mesh, P(dp, cp, None, None, None)))
    k = wsc(k, NamedSharding(mesh, P(dp, None, None, None)))
    v = wsc(v, NamedSharding(mesh, P(dp, None, None, None)))

    def kv_step(carry, kj):
        acc, m, denom = carry                       # (B,nq,blk,H,hd) f32 ...
        kb = jax.lax.dynamic_slice_in_dim(k, kj * block, block, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, kj * block, block, 1)
        kb = _repeat_kv(kb, n_rep)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bnqhd,bkhd->bnhqk", qb, kb).astype(jnp.float32)
        s = s * float(1.0 / np.sqrt(hd))
        qpos = (jnp.arange(nq)[:, None] * block
                + jnp.arange(block)[None, :])       # (nq, blk)
        kpos = kj * block + jnp.arange(block)
        mask = kpos[None, None, :] <= qpos[:, :, None]
        if window:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(mask[None, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        scale = jnp.exp(m - m_new)
        pr = jnp.exp(s - m_new[..., None])
        denom = denom * scale + jnp.sum(pr, axis=-1)
        acc = acc * scale.transpose(0, 1, 3, 2)[..., None] + jnp.einsum(
            "bnhqk,bkhd->bnqhd", pr.astype(qb.dtype), vb).astype(jnp.float32)
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, nq, block, H, hd), jnp.float32)
    m0 = jnp.full((B, nq, H, block), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, nq, H, block), jnp.float32)
    (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0), jnp.arange(nq))
    out = acc / jnp.maximum(denom.transpose(0, 1, 3, 2)[..., None], 1e-30)
    out = wsc(out, NamedSharding(mesh, P(dp, cp, None, None, None)))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _attend_dense(q, k, v, n_rep, window):
    B, S, H, hd = q.shape
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores * float(1.0 / np.sqrt(hd))
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = ki <= qi
    if window:
        mask &= ki > qi - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _attend_chunked(q, k, v, n_rep, window, block, banded):
    """Online-softmax over KV blocks; optionally skip blocks outside the
    sliding-window band (the §Perf SWA optimization)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    nq = S // block
    q = q.reshape(B, nq, block, H, hd)

    def per_qblock(qi, qb):
        # qb: (B, block, H, hd); causal ⇒ only KV blocks ≤ qi matter.
        if banded and window:
            nkv = min(nq, window // block + 2)
        else:
            nkv = nq

        def kv_step(carry, kj):
            acc, m, denom = carry
            if banded and window:
                # absolute KV block index: the band [qi-nkv+1 .. qi]
                kb_idx = qi - (nkv - 1) + kj
            else:
                kb_idx = kj
            kb_idx_c = jnp.clip(kb_idx, 0, nq - 1)
            kb = jax.lax.dynamic_slice_in_dim(k, kb_idx_c * block, block, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, kb_idx_c * block, block, 1)
            kb = _repeat_kv(kb, n_rep)
            vb = _repeat_kv(vb, n_rep)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32)
            s = s * float(1.0 / np.sqrt(hd))
            qpos = qi * block + jnp.arange(block)[:, None]
            kpos = kb_idx_c * block + jnp.arange(block)[None, :]
            mask = (kpos <= qpos) & (kb_idx >= 0)
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            scale = jnp.exp(m - m_new)
            pr = jnp.exp(s - m_new[..., None])
            denom = denom * scale + jnp.sum(pr, axis=-1)
            acc = acc * scale[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", pr.astype(qb.dtype), vb).astype(jnp.float32)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, H, block, hd), jnp.float32)
        m0 = jnp.full((B, H, block), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, H, block), jnp.float32)
        kj_hi = nkv if (banded and window) else (qi + 1)
        # scan over a static-length block range; mask handles the remainder
        def masked_step(carry, kj):
            pred = kj < kj_hi if not (banded and window) else kj < nkv
            new_carry, _ = kv_step(carry, kj)
            carry = jax.tree.map(
                lambda n, c: jnp.where(pred, n, c), new_carry, carry)
            return carry, None

        (acc, m, denom), _ = jax.lax.scan(
            masked_step, (acc0, m0, d0), jnp.arange(nkv))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(qb.dtype)   # (B, block, H, hd)

    out = jax.lax.map(lambda args: per_qblock(*args),
                      (jnp.arange(nq), q.transpose(1, 0, 2, 3, 4)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


# --- decode ----------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KV, hd)
    v: jax.Array
    pos: jax.Array        # () int32 — next write position (same for batch)


def init_cache(B: int, S_max: int, cfg, dtype) -> KVCache:
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return KVCache(k=jnp.zeros((B, S_max, KV, hd), dtype),
                   v=jnp.zeros((B, S_max, KV, hd), dtype),
                   pos=jnp.zeros((), jnp.int32))


def decode_attention(x: jax.Array, p: dict, cfg, cache: KVCache):
    """One-token decode: x (B,1,D); returns (out (B,1,D), new cache)."""
    B, _, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    S_max = cache.k.shape[1]
    window = cfg.sliding_window
    # rotary position = absolute position; cache slot wraps for SWA ring
    abs_pos = cache.pos
    slot = abs_pos % S_max if window else abs_pos
    q, k, v = _qkv(x, p, cfg, jnp.broadcast_to(abs_pos, (B, 1)))
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    kk = _repeat_kv(ck, H // KV)
    vv = _repeat_kv(cv, H // KV)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) \
        * float(1.0 / np.sqrt(hd))
    kpos = jnp.arange(S_max)
    valid = kpos <= abs_pos if not window \
        else (kpos[None, :] >= 0) & jnp.ones((1, S_max), bool)   # ring: all slots ≤ window
    if window:
        filled = jnp.minimum(abs_pos + 1, S_max)
        valid = kpos[None, :] < filled
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    else:
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vv)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, KVCache(ck, cv, abs_pos + 1)
