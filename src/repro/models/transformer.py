"""Unified model: block definitions, scanned stacks, LM loss, decode step.

One config-driven model covers all 10 assigned architectures:

  dense/vlm : [attn + mlp] × L                   (llama, qwen, nemotron,
              mistral-large, chameleon)
  moe       : [attn + moe] × L                   (mixtral, granite)
  audio     : [attn + mlp] × L over frame embeddings, 4 codebook heads
  ssm       : [rwkv6 timemix + channelmix] × L   (rwkv6)
  hybrid    : mamba2 × L with a *shared* attn+mlp block applied every
              ``attn_every`` layers (zamba2)

Layers are scanned (stacked params) with configurable remat — compile time
stays O(1) in depth, which is what makes the 96-layer dry-runs tractable.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .attention import KVCache, decode_attention, init_attention, init_cache
from .layers import cross_entropy, embed, init_embed, init_mlp, init_rms, \
    mlp, rms_norm


# ---------------------------------------------------------------------------
# Per-layer init/apply
# ---------------------------------------------------------------------------


def _init_attn_block(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": init_rms(cfg.d_model),
         "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, cfg.qk_norm, dtype),
         "ln2": init_rms(cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(k2, cfg.d_model, cfg.d_ff,
                                    cfg.n_experts, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff,
                            gated=(cfg.act == "silu"), dtype=dtype)
    return p


def _apply_attn_block(x, p, cfg, mesh, data_axes):
    h = attn_mod.attention(rms_norm(x, p["ln1"]), p["attn"], cfg, mesh=mesh)
    x = x + h
    if "moe" in p:
        y, aux = moe_mod.moe_apply(rms_norm(x, p["ln2"]), p["moe"], cfg,
                                   mesh, data_axes=data_axes)
    else:
        y, aux = mlp(rms_norm(x, p["ln2"]), p["mlp"], cfg.act), 0.0
    return x + y, aux


def _init_rwkv_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms(cfg.d_model),
            "time": ssm_mod.init_rwkv6(k1, cfg.d_model, cfg.n_heads, dtype),
            "ln2": init_rms(cfg.d_model),
            "chan": ssm_mod.init_rwkv_channelmix(k2, cfg.d_model, cfg.d_ff,
                                                 dtype)}


def _apply_rwkv_block(x, p, cfg):
    h = ssm_mod.rwkv6(rms_norm(x, p["ln1"]), p["time"], cfg)
    x = x + h
    xn = rms_norm(x, p["ln2"])
    xprev = jnp.concatenate([jnp.zeros_like(xn[:, :1]), xn[:, :-1]], axis=1)
    return x + ssm_mod.rwkv_channelmix(xn, xprev, p["chan"]), 0.0


def _init_mamba_block(key, cfg, dtype):
    return {"ln": init_rms(cfg.d_model),
            "mamba": ssm_mod.init_mamba2(key, cfg.d_model, cfg.ssm_heads,
                                         cfg.ssm_state, dtype)}


def _apply_mamba_block(x, p, cfg):
    return x + ssm_mod.mamba2(rms_norm(x, p["ln"]), p["mamba"], cfg), 0.0


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    if cfg.family != "audio":                    # audio: frontend stub
        params["embed"] = init_embed(keys[0], cfg.vocab, cfg.d_model, dtype)
    params["norm_f"] = init_rms(cfg.d_model)
    if cfg.family == "audio":
        params["heads"] = jax.random.normal(
            keys[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab), dtype) * 0.02
    elif not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), dtype) * 0.02

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        init_one = lambda k: _init_attn_block(k, cfg, dtype)
    elif cfg.family == "ssm":
        init_one = lambda k: _init_rwkv_block(k, cfg, dtype)
    elif cfg.family == "hybrid":
        init_one = lambda k: _init_mamba_block(k, cfg, dtype)
        params["shared"] = _init_attn_block(keys[2], cfg, dtype)
    else:
        raise ValueError(cfg.family)
    lkeys = jax.random.split(keys[3], cfg.n_layers)
    params["blocks"] = jax.vmap(init_one)(lkeys)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)                    # "full": save nothing


def _scan_stack(x, blocks, apply_one, remat_mode, mesh=None,
                seq_shard=False, batch_axes=None):
    from repro.dist.sharding import shard_act
    seq_axis = "model" if seq_shard else None

    def body(carry, layer_params):
        h, aux = carry
        # sequence-parallel carry: the saved residual stack (the dominant
        # live buffer under remat) shards over the model axis; GSPMD
        # all-gathers at the attention boundary and reduce-scatters back.
        h = shard_act(h, mesh, seq_axis, None, axes=batch_axes)
        h, a = apply_one(h, layer_params)
        return (h, aux + a), None

    body = _remat(body, remat_mode)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def forward(params, inputs: Dict[str, jax.Array], cfg, mesh=None,
            data_axes=("data",), last_only: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """Returns (logits, aux_loss).  inputs: {'tokens'} or {'embeds'}."""
    from repro.dist.sharding import shard_act
    batch_axes = None
    if mesh is not None and getattr(cfg, "ddp", False):
        from repro.dist.sharding import batch_axes_of
        B0 = (inputs.get("tokens") if "tokens" in inputs
              else inputs["embeds"]).shape[0]
        batch_axes = batch_axes_of(mesh, cfg, batch=B0)
    if cfg.family == "audio":
        x = inputs["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed(inputs["tokens"], params["embed"])
    x = shard_act(x, mesh, None, None, axes=batch_axes)

    seq_shard = bool(getattr(cfg, "act_seq_shard", False)) and \
        mesh is not None and "model" in getattr(mesh, "shape", {}) and \
        x.shape[1] % mesh.shape["model"] == 0
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        apply_one = lambda h, p: _apply_attn_block(h, p, cfg, mesh, data_axes)
        x, aux = _scan_stack(x, params["blocks"], apply_one, cfg.remat, mesh,
                             seq_shard, batch_axes)
    elif cfg.family == "ssm":
        apply_one = lambda h, p: _apply_rwkv_block(h, p, cfg)
        x, aux = _scan_stack(x, params["blocks"], apply_one, cfg.remat, mesh,
                             seq_shard, batch_axes)
    elif cfg.family == "hybrid":
        x, aux = _hybrid_forward(x, params, cfg, mesh, data_axes)
    else:
        raise ValueError(cfg.family)

    if last_only:
        x = x[:, -1:]                # prefill serves next-token logits only
    x = shard_act(rms_norm(x, params["norm_f"]), mesh, None, None,
                  axes=batch_axes)
    if cfg.family == "audio":
        logits = jnp.einsum("bsd,cdv->bscv", x, params["heads"])
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    return logits, aux


def _hybrid_forward(x, params, cfg, mesh, data_axes):
    """zamba2: groups of `attn_every` mamba layers + one shared attn block."""
    every = cfg.attn_every
    L = cfg.n_layers
    n_groups = L // every
    blocks = params["blocks"]
    aux_total = jnp.zeros((), jnp.float32)
    apply_m = lambda h, p: _apply_mamba_block(h, p, cfg)
    for g in range(n_groups):
        grp = jax.tree.map(lambda a: a[g * every:(g + 1) * every], blocks)
        x, _ = _scan_stack(x, grp, apply_m, cfg.remat, mesh)
        shared = _remat(
            lambda h, p: _apply_attn_block(h, p, cfg, mesh, data_axes),
            cfg.remat)
        x, aux = shared(x, params["shared"])
        aux_total = aux_total + aux
    rem = L - n_groups * every
    if rem:
        grp = jax.tree.map(lambda a: a[n_groups * every:], blocks)
        x, _ = _scan_stack(x, grp, apply_m, cfg.remat, mesh)
    return x, aux_total


def loss_fn(params, batch: Dict[str, jax.Array], cfg, mesh=None,
            data_axes=("data",)) -> jax.Array:
    logits, aux = forward(params, batch, cfg, mesh, data_axes)
    # audio: logits (B,S,Cb,V) vs labels (B,S,Cb); LM: (B,S,V) vs (B,S)
    loss = cross_entropy(logits, batch["labels"])
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    caches: Any            # stacked KVCache / MambaState / RWKVState
    shared_caches: Any     # hybrid only
    pos: jax.Array


def init_decode_state(cfg, B: int, cache_len: int, dtype) -> DecodeState:
    L = cfg.n_layers
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        S = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
            else cache_len
        mk = lambda _: init_cache(B, S, cfg, dtype)
        caches = jax.vmap(mk)(jnp.arange(L))
        return DecodeState(caches, None, jnp.zeros((), jnp.int32))
    if cfg.family == "ssm":
        hd = cfg.d_model // cfg.n_heads
        mk = lambda _: ssm_mod.RWKVState(
            wkv=jnp.zeros((B, cfg.n_heads, hd, hd), jnp.float32),
            last=jnp.zeros((B, cfg.d_model), jnp.float32))
        return DecodeState(jax.vmap(mk)(jnp.arange(L)), None,
                           jnp.zeros((), jnp.int32))
    if cfg.family == "hybrid":
        di = 2 * cfg.d_model
        hd = di // cfg.ssm_heads
        mk = lambda _: ssm_mod.MambaState(
            ssm=jnp.zeros((B, cfg.ssm_heads, hd, cfg.ssm_state), jnp.float32),
            conv=jnp.zeros((B, 3, di + 2 * cfg.ssm_state), jnp.dtype(cfg.dtype)))
        caches = jax.vmap(mk)(jnp.arange(L))
        n_sh = cfg.n_layers // cfg.attn_every
        mk2 = lambda _: init_cache(B, cache_len, cfg, dtype)
        return DecodeState(caches, jax.vmap(mk2)(jnp.arange(n_sh)),
                           jnp.zeros((), jnp.int32))
    raise ValueError(cfg.family)


def decode_step(params, state: DecodeState, inputs: Dict[str, jax.Array],
                cfg, mesh=None, data_axes=("data",)):
    """One-token decode.  inputs: {'tokens': (B,1)} or {'embeds': (B,1,D)}."""
    from repro.dist.sharding import shard_act
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        x = inputs["embeds"].astype(dtype)
    else:
        x = embed(inputs["tokens"], params["embed"])
    x = shard_act(x, mesh, None, None)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(h, inp):
            p, cache = inp
            h = shard_act(h, mesh, None, None)
            a, new_cache = decode_attention(
                rms_norm(h, p["ln1"]), p["attn"], cfg,
                KVCache(cache.k, cache.v, state.pos))
            h = h + a
            if "moe" in p:
                y, _ = moe_mod.moe_apply(rms_norm(h, p["ln2"]), p["moe"], cfg,
                                         mesh, data_axes=data_axes)
            else:
                y = mlp(rms_norm(h, p["ln2"]), p["mlp"], cfg.act)
            return h + y, new_cache

        x, caches = jax.lax.scan(body, x, (params["blocks"], state.caches))
        new_state = DecodeState(caches, None, state.pos + 1)
    elif cfg.family == "ssm":
        def body(h, inp):
            p, st = inp
            a, new_st = ssm_mod.rwkv6_decode(rms_norm(h, p["ln1"]), p["time"],
                                             cfg, st)
            h = h + a
            xn = rms_norm(h, p["ln2"])
            # decode-time token shift: previous-token features are not
            # tracked for the channel mix (zero shift — documented
            # simplification; the time-mix state *is* exact).
            y = ssm_mod.rwkv_channelmix(xn[:, 0], jnp.zeros_like(xn[:, 0]),
                                        p["chan"])[:, None]
            return h + y, new_st

        x, caches = jax.lax.scan(body, x, (params["blocks"], state.caches))
        new_state = DecodeState(caches, None, state.pos + 1)
    elif cfg.family == "hybrid":
        every = cfg.attn_every
        n_groups = cfg.n_layers // every
        caches = state.caches
        sh_caches = state.shared_caches
        new_m, new_s = [], []
        h = x
        for g in range(n_groups):
            grp_p = jax.tree.map(lambda a: a[g * every:(g + 1) * every],
                                 params["blocks"])
            grp_c = jax.tree.map(lambda a: a[g * every:(g + 1) * every], caches)

            def mbody(hh, inp):
                p, st = inp
                out, nst = ssm_mod.mamba2_decode(rms_norm(hh, p["ln"]),
                                                 p["mamba"], cfg, st)
                return hh + out, nst

            h, nc = jax.lax.scan(mbody, h, (grp_p, grp_c))
            new_m.append(nc)
            shc = jax.tree.map(lambda a: a[g], sh_caches)
            a, nshc = decode_attention(
                rms_norm(h, params["shared"]["ln1"]), params["shared"]["attn"],
                cfg, KVCache(shc.k, shc.v, state.pos))
            h = h + a
            y = mlp(rms_norm(h, params["shared"]["ln2"]),
                    params["shared"]["mlp"], cfg.act)
            h = h + y
            new_s.append(nshc)
        x = h
        caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_m)
        sh_caches = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_s)
        new_state = DecodeState(caches, sh_caches, state.pos + 1)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["norm_f"])
    if cfg.family == "audio":
        logits = jnp.einsum("bsd,cdv->bscv", x, params["heads"])
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    return logits, new_state
