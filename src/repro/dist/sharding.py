"""Mesh-axis conventions and sharding rules for the model stack.

Axis roles (see ``repro.launch.mesh``):
  * ``pod``/``data`` — batch parallelism (gradients reduced across);
  * ``model``        — tensor parallelism (weights split, GSPMD inserts the
    collectives);
  * ``sort``         — the sorting meshes; never used by the model code.
    :func:`sort_mesh` builds the (data, sort) 2-D layout for batched
    ``psort``: d independent sort problems, each over a p-sized sort-axis
    subgroup (collectives named over ``sort`` stay inside a row).

``make_shardings`` assigns a :class:`NamedSharding` to every parameter /
optimizer leaf with one shape-driven rule: split the largest
model-divisible non-leading dimension over ``model`` (the leading dimension
of block params is the scanned layer stack and stays replicated), falling
back to replication.  Any NamedSharding is *numerically* equivalent — GSPMD
treats it as a layout constraint — so the rule optimizes memory without
affecting results; ``cfg.ddp`` replicates weights entirely (the
small-model regime, where the batch spans data × model instead).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def sort_mesh(p: Optional[int] = None, d: int = 1, *, axis: str = "sort",
              data_axis: str = "data",
              shape: Optional[Tuple[int, int]] = None,
              mesh_axes: Tuple[str, str] = ("inter", "intra"),
              devices=None, exclude: Tuple[int, ...] = ()) -> Mesh:
    """A device mesh for ``psort``: flat (d, p) or hierarchical nested.

    Flat form (default): a (d, p) mesh with axes (``data_axis``, ``axis``)
    — row r of a (d, n) key batch lives on the r-th data-axis slice and is
    sorted by the p devices of its sort-axis subgroup.  ``p`` defaults to
    ``len(devices) // d`` — every available device joins some subgroup.

    Hierarchical form — ``shape=(p_outer, p_inner)`` builds the nested
    (``data_axis``?, *inter*, *intra*) mesh that hierarchy-aware ``psort``
    sorts over: the outer ``mesh_axes[0]`` is the slow (inter-host) axis
    carrying exactly one AMS level's all_to_all, the inner ``mesh_axes[1]``
    the fast (intra-host) axis every other level recurses inside.  The
    data axis leads only when ``d > 1`` (batched keys).  Flat PE index =
    ``outer · p_inner + inner``, so enumerating the nested mesh in row-major
    order visits the same devices as the flat mesh of ``p_outer·p_inner``.

    ``exclude`` drops devices by their *position* in the device list
    before the mesh is laid out — the elastic rescale path
    (``repro.runtime.elastic.plan_sort_rescale``): failed flat PE ranks
    are excluded and the survivors renumber contiguously into the reduced
    mesh (pass the plan's ``p_new``/``mesh_shape`` as ``p``/``shape``).
    Axis *names* are unchanged, so every sharding rule re-derives.

    >>> import jax
    >>> m = sort_mesh(shape=(1, 1), devices=jax.devices()[:1])
    >>> [(a, m.shape[a]) for a in m.axis_names]
    [('inter', 1), ('intra', 1)]
    """
    devs = list(devices) if devices is not None else jax.devices()
    if exclude:
        bad = {int(i) for i in exclude}
        out_of_range = bad - set(range(len(devs)))
        if out_of_range:
            raise ValueError(f"exclude={sorted(bad)} names device positions "
                             f"outside 0..{len(devs) - 1}")
        devs = [dv for i, dv in enumerate(devs) if i not in bad]
    if d < 1:
        raise ValueError(f"d={d} must be >= 1")
    if shape is not None:
        if p is not None and p != int(np.prod(shape)):
            raise ValueError(f"p={p} inconsistent with shape={tuple(shape)}")
        p_o, p_i = (int(v) for v in shape)
        if p_o < 1 or p_i < 1 or d * p_o * p_i > len(devs):
            raise ValueError(f"requested mesh ({d}, {p_o}, {p_i}) needs "
                             f"{d * p_o * p_i} devices; have {len(devs)}")
        dims = (d, p_o, p_i) if d > 1 else (p_o, p_i)
        names = ((data_axis,) if d > 1 else ()) + tuple(mesh_axes)
        return Mesh(np.array(devs[:d * p_o * p_i]).reshape(dims), names)
    p = p if p is not None else len(devs) // d
    if p < 1 or d * p > len(devs):
        raise ValueError(f"requested mesh ({d}, {p}) needs {d * p} devices; "
                         f"have {len(devs)}")
    return Mesh(np.array(devs[:d * p]).reshape(d, p), (data_axis, axis))


def data_axes_of(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    """Mesh axes that carry batch parallelism, outermost first."""
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _size(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def batch_axes_of(mesh: Optional[Mesh], cfg=None,
                  batch: Optional[int] = None) -> Tuple[str, ...]:
    """Axes the batch dimension shards over.

    Under ``cfg.ddp`` the model axis joins the batch axes (weights are
    replicated, so every rank can take a batch slice).  Axes are dropped
    innermost-first until ``batch`` divides the axis product.
    """
    if mesh is None:
        return ()
    axes = list(data_axes_of(mesh))
    if cfg is not None and getattr(cfg, "ddp", False) and "model" in mesh.shape:
        axes.append("model")
    if batch is not None:
        while axes and batch % _size(mesh, axes) != 0:
            axes.pop()
    return tuple(axes)


def shard_act(x: jax.Array, mesh: Optional[Mesh],
              seq_axis: Optional[str] = None, d_axis: Optional[str] = None,
              axes: Optional[Tuple[str, ...]] = None) -> jax.Array:
    """Constrain an activation ``(B, S, ..., D)`` to the mesh layout.

    ``axes`` shards the batch dim (default: the data axes when the batch
    divides them); ``seq_axis``/``d_axis`` shard dims 1 / -1.  Callers
    guarantee divisibility for the axes they pass explicitly.
    """
    if mesh is None or x.ndim < 2:
        return x
    if axes is None:
        axes = data_axes_of(mesh)
        if _size(mesh, axes) == 0 or x.shape[0] % max(1, _size(mesh, axes)):
            axes = ()
    spec = [tuple(axes) or None] + [None] * (x.ndim - 1)
    if seq_axis is not None and x.ndim >= 3:
        spec[1] = seq_axis
    if d_axis is not None:
        spec[-1] = d_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def make_shardings(tree, cfg, mesh: Optional[Mesh]):
    """NamedSharding pytree for parameters / optimizer state.

    Works on concrete arrays or ``jax.eval_shape`` outputs — anything with
    ``.shape``.
    """
    if mesh is None:
        return jax.tree.map(lambda _: None, tree)
    model = mesh.shape.get("model", 1)
    ddp = getattr(cfg, "ddp", False) if cfg is not None else False

    def rule(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if model > 1 and not ddp and len(shape) >= 2:
            cands = [i for i in range(1, len(shape))
                     if shape[i] >= model and shape[i] % model == 0]
            if cands:
                spec[max(cands, key=lambda i: shape[i])] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(rule, tree)
