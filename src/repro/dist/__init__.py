from .sharding import (batch_axes_of, data_axes_of, make_shardings,  # noqa: F401
                       shard_act)
