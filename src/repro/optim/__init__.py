from .optimizers import (adamw_init, adamw_update, adafactor_init,  # noqa: F401
                         adafactor_update, make_optimizer)
from .schedule import cosine_schedule                               # noqa: F401
from .grad_compress import compressed_psum, init_error_feedback    # noqa: F401
