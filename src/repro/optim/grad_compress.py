"""Gradient compression: int8 quantized reduce-scatter/all-gather psum with
error feedback (1-bit-Adam-style residual correction).

Replaces a full-precision all-reduce (4·B bytes on the wire) with an int8
reduce-scatter + int8 all-gather (≈1·B each way ⇒ ~4× collective-byte
reduction, visible to the HLO collective parser used by §Roofline).  Error
feedback keeps the *accumulated* quantization error bounded, so SGD-style
convergence is preserved (unit-tested on a quadratic in tests/).

Used by the DDP training path (replicated params, ≤ few-B models); the
FSDP/GSPMD path keeps XLA's fused reduce-scatter.

Collectives go through ``repro.core.comm``, so the same body runs inside
``shard_map`` (production) and under ``comm.sim_map`` (single-process sim
backend at high emulated PE counts) — and is countable with
``comm.counting()``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import comm


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(g: jax.Array, err: jax.Array, axis_name: str,
                         p: int) -> Tuple[jax.Array, jax.Array]:
    """Mean-all-reduce one gradient leaf with int8 wire format.

    Call inside shard_map over ``axis_name``.  Returns (mean_grad, new_err).
    """
    flat = g.astype(jnp.float32).reshape(-1) + err.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % p
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    chunks = flat.reshape(p, -1)

    q, scale = _quant(chunks)
    err_new = (flat - (q.astype(jnp.float32) * scale).reshape(-1))[:n]
    # reduce-scatter: all-to-all the int8 chunks (+ per-src scales), sum local
    qs = comm.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                         tiled=True).reshape(p, -1)
    scales = comm.all_gather(scale, axis_name)                 # (p,)
    mine = jnp.sum(qs.astype(jnp.float32) * scales[:, None], axis=0) / p
    # all-gather the reduced shard, again int8 on the wire
    q2, scale2 = _quant(mine)
    allq = comm.all_gather(q2, axis_name, tiled=True)          # (n+pad,) int8
    alls = comm.all_gather(scale2, axis_name)                  # (p,)
    shard_len = mine.shape[0]
    out = (allq.astype(jnp.float32).reshape(p, shard_len)
           * alls[:, None]).reshape(-1)[:n]
    return out.reshape(g.shape), err_new.reshape(g.shape)


def compressed_psum(grads, err_state, axis_name: str, p: int):
    """Tree-mapped compressed mean-all-reduce."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [compressed_psum_mean(g, e, axis_name, p)
            for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e
