"""LR schedules."""
import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, s / jnp.maximum(warmup, 1))
    t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, peak_lr * cos)
