"""Optimizers: AdamW (≤100B configs) and Adafactor (factored second moment
for the 100B+ dense models, where Adam's 12 bytes/param cannot fit
256 × 16 GiB — DESIGN.md §6).  Pure pytree implementations; states inherit
the parameter sharding (FSDP) via GSPMD."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      step=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(new_m, new_v, step)


class AdafactorState(NamedTuple):
    vr: Any              # row statistics (or full v for <2D params)
    vc: Any              # col statistics
    step: jax.Array


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
            else jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if _factored(p) else jnp.zeros((1,), jnp.float32)

    return AdafactorState(vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params),
                          step=jnp.zeros((), jnp.int32))


def adafactor_update(grads, state: AdafactorState, params, *, lr,
                     decay=0.8, eps=1e-30, clip=1.0, weight_decay=0.0):
    step = state.step + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p):
            vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps))
            cfac = jax.lax.rsqrt(vc)
            u = g * rfac[..., None] * cfac[..., None, :]
        else:
            vr = beta * vr + (1 - beta) * g2
            u = g * jax.lax.rsqrt(vr)
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    is_t = lambda x: isinstance(x, tuple)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    new_r = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    new_c = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
    return new_p, AdafactorState(new_r, new_c, step)


def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)
