"""Pure-jnp oracles for the bitonic sort / merge kernels."""
import jax.numpy as jnp


def sort_tile_ref(keys, vals=None):
    if vals is None:
        return jnp.sort(keys)
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def merge_tiles_ref(a, b, av=None, bv=None):
    keys = jnp.concatenate([a, b])
    if av is None:
        return jnp.sort(keys)
    vals = jnp.concatenate([av, bv])
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]
