"""Pallas TPU kernels: bitonic sort network + bitonic 2-way merge.

Local sorting/merging is the compute hot spot of every algorithm in the
paper (the O((n/p)·log n) term of Table I).  On TPU we sort a VMEM-resident
tile laid out as (R, 128) — flat element index f = r·128 + l — with the
classic Batcher network expressed entirely in vector ops:

  * exchange distance 2^j ≥ 128: partner lives in another *sublane row*
    (reshape to (R/2m, 2, m, 128), flip the pair axis);
  * exchange distance 2^j < 128:  partner lives in another *lane*
    (reshape the lane dim to (…, 2, m), flip) — a lane permute on the VPU.

No gathers, no scalar loops: every compare-exchange is a full-tile vector
op, and the network is unrolled at trace time (log²(C)/2 steps).  Ties are
broken by flat index so that (key, payload) pairs are exchanged
consistently — both partners compute identical swap decisions.

Keys are uint32 (order-preserving transforms in ops.py); an optional uint32
payload plane travels along.  The MXU is not used — sorting is a pure VPU
workload; the kernel's job is keeping the working set in VMEM across all
O(log² C) passes instead of round-tripping HBM per pass (the HBM-bound
alternative), cf. EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _partner(x: jax.Array, j: int) -> jax.Array:
    """Value of the partner element f ^ 2^j for every f (layout-aware)."""
    R = x.shape[0]
    if (1 << j) >= LANES:                       # sublane exchange
        m = (1 << j) // LANES
        return jnp.flip(x.reshape(R // (2 * m), 2, m, LANES), axis=1
                        ).reshape(R, LANES)
    m = 1 << j                                  # lane exchange
    return jnp.flip(x.reshape(R, LANES // (2 * m), 2, m), axis=2
                    ).reshape(R, LANES)


def _flat_bit(R: int, j: int) -> jax.Array:
    """(f >> j) & 1 for the (R,128) layout, as a bool plane."""
    r = jax.lax.broadcasted_iota(jnp.int32, (R, LANES), 0)
    l = jax.lax.broadcasted_iota(jnp.int32, (R, LANES), 1)
    f = r * LANES + l
    return ((f >> j) & 1) == 1


def _compare_exchange(keys, vals, j: int, want_min):
    """One network step at distance 2^j. ``want_min``: bool plane."""
    pk = _partner(keys, j)
    upper = _flat_bit(keys.shape[0], j)         # my bit j set ⇒ I am f|2^j
    # strict order with index tie-break: am I the smaller of the pair?
    am_lower = (keys < pk) | ((keys == pk) & ~upper)
    take_self = am_lower == want_min
    out_k = jnp.where(take_self, keys, pk)
    out_v = None
    if vals is not None:
        pv = _partner(vals, j)
        out_v = jnp.where(take_self, vals, pv)
    return out_k, out_v


def _sort_network(keys, vals):
    R = keys.shape[0]
    n = R * LANES
    d = int(math.log2(n))
    for k in range(d):                          # stage: bitonic blocks 2^(k+1)
        for j in range(k, -1, -1):
            up = ~_flat_bit(R, k + 1)           # block direction
            want_min = ~_flat_bit(R, j) == up
            keys, vals = _compare_exchange(keys, vals, j, want_min)
    return keys, vals


def _merge_network(keys, vals):
    """Inputs: [first half ascending | second half descending] (bitonic)."""
    R = keys.shape[0]
    n = R * LANES
    d = int(math.log2(n))
    for j in range(d - 1, -1, -1):
        want_min = ~_flat_bit(R, j)             # ascending everywhere
        keys, vals = _compare_exchange(keys, vals, j, want_min)
    return keys, vals


def _sort_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref):
    k, v = _sort_network(keys_ref[...],
                         vals_ref[...] if vals_ref is not None else None)
    out_k_ref[...] = k
    if out_v_ref is not None:
        out_v_ref[...] = v


def _merge_kernel(a_ref, b_ref, av_ref, bv_ref, out_k_ref, out_v_ref):
    # reverse b to form a bitonic sequence, then one merge chain
    b = jnp.flip(b_ref[...].reshape(-1)).reshape(b_ref.shape)
    keys = jnp.concatenate([a_ref[...], b], axis=0)
    vals = None
    if av_ref is not None:
        bv = jnp.flip(bv_ref[...].reshape(-1)).reshape(bv_ref.shape)
        vals = jnp.concatenate([av_ref[...], bv], axis=0)
    k, v = _merge_network(keys, vals)
    out_k_ref[...] = k
    if out_v_ref is not None:
        out_v_ref[...] = v


def _specs(R: int, n_tiles: int = 1):
    return pl.BlockSpec((R, LANES), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_tile(keys: jax.Array, vals=None, *, interpret: bool = True):
    """Sort a (R·128,)-element tile fully inside VMEM.  R·128 ≤ 64Ki words
    keeps keys+vals+double-buffering well under the 16 MiB VMEM budget."""
    n = keys.shape[0]
    R = n // LANES
    assert n % LANES == 0 and (n & (n - 1)) == 0, "tile must be 2^k·128"
    k2 = keys.reshape(R, LANES)
    if vals is None:
        out = pl.pallas_call(
            lambda kr, ok: _sort_kernel(kr, None, ok, None),
            out_shape=jax.ShapeDtypeStruct((R, LANES), keys.dtype),
            in_specs=[_specs(R)], out_specs=_specs(R),
            grid=(1,), interpret=interpret)(k2)
        return out.reshape(n)
    v2 = vals.reshape(R, LANES)
    ok, ov = pl.pallas_call(
        _sort_kernel,
        out_shape=(jax.ShapeDtypeStruct((R, LANES), keys.dtype),
                   jax.ShapeDtypeStruct((R, LANES), vals.dtype)),
        in_specs=[_specs(R), _specs(R)], out_specs=(_specs(R), _specs(R)),
        grid=(1,), interpret=interpret)(k2, v2)
    return ok.reshape(n), ov.reshape(n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_tiles(a: jax.Array, b: jax.Array, av=None, bv=None, *,
                interpret: bool = True):
    """Merge two sorted tiles of equal power-of-two size (≥128 each)."""
    n = a.shape[0]
    R = n // LANES
    assert a.shape == b.shape and n % LANES == 0
    a2, b2 = a.reshape(R, LANES), b.reshape(R, LANES)
    spec_in = pl.BlockSpec((R, LANES), lambda i: (i, 0))
    spec_out = pl.BlockSpec((2 * R, LANES), lambda i: (i, 0))
    if av is None:
        out = pl.pallas_call(
            lambda ar, br, ok: _merge_kernel(ar, br, None, None, ok, None),
            out_shape=jax.ShapeDtypeStruct((2 * R, LANES), a.dtype),
            in_specs=[spec_in, spec_in], out_specs=spec_out,
            grid=(1,), interpret=interpret)(a2, b2)
        return out.reshape(2 * n)
    ok, ov = pl.pallas_call(
        _merge_kernel,
        out_shape=(jax.ShapeDtypeStruct((2 * R, LANES), a.dtype),
                   jax.ShapeDtypeStruct((2 * R, LANES), av.dtype)),
        in_specs=[spec_in] * 4, out_specs=(spec_out, spec_out),
        grid=(1,), interpret=interpret)(a2, b2, av.reshape(R, LANES),
                                        bv.reshape(R, LANES))
    return ok.reshape(2 * n), ov.reshape(2 * n)
