from .ops import local_sort_fast, supported          # noqa: F401
from .bitonic import sort_tile, merge_tiles          # noqa: F401
