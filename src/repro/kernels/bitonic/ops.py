"""Jitted public wrappers around the bitonic Pallas kernels.

``local_sort_fast(keys, vals)`` sorts **arbitrary sizes**: non-power-of-two
inputs are padded up to the next power of two with ``pad_val`` and sliced
back after the sort, so real shard capacities take the kernel path.  Tiles
≤ ``MAX_TILE`` are sorted by one kernel launch; larger inputs are sorted
tile-wise and combined with log(n/MAX_TILE) merge-kernel passes.  Only
4-byte words lower to the TPU kernel — 64-bit keys fall back to the jnp
reference.

Padding caveat (shared with the power-of-two path, whose capacity padding
has the same property): the bitonic network is *not stable*.  ``pad_val``
defaults to the dtype's maximum (+inf for floats) and pads sort to the
back; but when a payload travels along and real keys *equal* the pad
value, a pad entry's payload may be exchanged with a real max-key
element's payload.  Callers that sort max-representable keys with payloads
should pass a ``pad_val`` known to be absent from the data, or use the
stable jnp path (``use_kernel=False``).

The kernels execute in ``interpret=True`` mode on CPU (this container);
on TPU the same ``pallas_call`` lowers to Mosaic with the BlockSpecs
declared in bitonic.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitonic
from .bitonic import LANES

MAX_TILE = 1 << 14          # 16Ki elements/tile: 64 KiB keys + 64 KiB vals


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def supported(n: int, dtype) -> bool:
    """Does ``local_sort_fast`` take the kernel path for (n, dtype)?
    Any positive size qualifies (pad-to-pow2); only 4-byte words lower."""
    return n > 0 and jnp.dtype(dtype).itemsize == 4


def _default_pad(dtype):
    dt = jnp.dtype(dtype)
    if dt == jnp.dtype(jnp.float32):
        return jnp.float32(jnp.inf)
    return jnp.iinfo(dt).max


def local_sort_fast(keys: jax.Array, vals=None, *, interpret: bool = True,
                    use_kernel: bool = True, pad_val=None):
    """Sort keys (u32/i32/f32) ascending, carrying an optional u32 payload.

    ``pad_val`` fills the pad-to-power-of-two tail (default: dtype max /
    +inf) — it must compare ≥ every real key; see the module docstring for
    the max-key payload caveat."""
    n = keys.shape[0]
    if not (use_kernel and supported(n, keys.dtype)):
        return bitonic_ref(keys, vals)
    m = max(LANES, _next_pow2(n))
    if m != n:
        if pad_val is None:
            pad_val = _default_pad(keys.dtype)
        keys = jnp.concatenate(
            [keys, jnp.full((m - n,), pad_val, keys.dtype)])
        if vals is not None:
            vals = jnp.concatenate(
                [vals, jnp.zeros((m - n,), vals.dtype)])
        if vals is None:
            return _sort_pow2(keys, None, interpret)[:n]
        ks, vs = _sort_pow2(keys, vals, interpret)
        return ks[:n], vs[:n]
    return _sort_pow2(keys, vals, interpret)


def _sort_pow2(keys, vals, interpret):
    n = keys.shape[0]
    if n <= MAX_TILE:
        return bitonic.sort_tile(keys, vals, interpret=interpret)
    # tile-wise sort + log2(n/tile) merge passes
    t = MAX_TILE
    if vals is None:
        tiles = [bitonic.sort_tile(keys[i:i + t], interpret=interpret)
                 for i in range(0, n, t)]
        while len(tiles) > 1:
            tiles = [bitonic.merge_tiles(tiles[i], tiles[i + 1],
                                         interpret=interpret)
                     for i in range(0, len(tiles), 2)]
        return tiles[0]
    pairs = [bitonic.sort_tile(keys[i:i + t], vals[i:i + t],
                               interpret=interpret) for i in range(0, n, t)]
    while len(pairs) > 1:
        pairs = [bitonic.merge_tiles(pairs[i][0], pairs[i + 1][0],
                                     pairs[i][1], pairs[i + 1][1],
                                     interpret=interpret)
                 for i in range(0, len(pairs), 2)]
    return pairs[0]


def bitonic_ref(keys, vals=None):
    from . import ref
    return ref.sort_tile_ref(keys, vals)
