"""Jitted public wrappers around the bitonic Pallas kernels.

``local_sort_fast(keys, vals)`` sorts arbitrary power-of-two sizes:
tiles ≤ ``MAX_TILE`` are sorted by one kernel launch; larger inputs are
sorted tile-wise and combined with log(n/MAX_TILE) merge-kernel passes.
Falls back to jnp for sizes/dtypes the TPU kernel does not target
(non-128-multiples, 64-bit words).

The kernels execute in ``interpret=True`` mode on CPU (this container);
on TPU the same ``pallas_call`` lowers to Mosaic with the BlockSpecs
declared in bitonic.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitonic
from .bitonic import LANES

MAX_TILE = 1 << 14          # 16Ki elements/tile: 64 KiB keys + 64 KiB vals


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def supported(n: int, dtype) -> bool:
    return (_is_pow2(n) and n >= LANES
            and jnp.dtype(dtype).itemsize == 4)


def local_sort_fast(keys: jax.Array, vals=None, *, interpret: bool = True,
                    use_kernel: bool = True):
    """Sort keys (u32/i32/f32) ascending, carrying an optional u32 payload."""
    n = keys.shape[0]
    if not (use_kernel and supported(n, keys.dtype)):
        return bitonic_ref(keys, vals)
    if n <= MAX_TILE:
        return bitonic.sort_tile(keys, vals, interpret=interpret)
    # tile-wise sort + log2(n/tile) merge passes
    t = MAX_TILE
    if vals is None:
        tiles = [bitonic.sort_tile(keys[i:i + t], interpret=interpret)
                 for i in range(0, n, t)]
        while len(tiles) > 1:
            tiles = [bitonic.merge_tiles(tiles[i], tiles[i + 1],
                                         interpret=interpret)
                     for i in range(0, len(tiles), 2)]
        return tiles[0]
    pairs = [bitonic.sort_tile(keys[i:i + t], vals[i:i + t],
                               interpret=interpret) for i in range(0, n, t)]
    while len(pairs) > 1:
        pairs = [bitonic.merge_tiles(pairs[i][0], pairs[i + 1][0],
                                     pairs[i][1], pairs[i + 1][1],
                                     interpret=interpret)
                 for i in range(0, len(pairs), 2)]
    return pairs[0]


def bitonic_ref(keys, vals=None):
    from . import ref
    return ref.sort_tile_ref(keys, vals)
