"""Pallas TPU kernels for the paper's compute hot spots.

bitonic/   — local sort + 2-way merge networks (VMEM-resident, VPU-only)
kway/      — Super Scalar Sample Sort k-way classifier with tie-breaking
partition/ — fused classify + histogram + in-bucket rank: the
             (bucket, send_pos, hist) triple feeding every all_to_all
             (what rams/samplesort/rquick actually call)

Each kernel ships ops.py (jit wrapper + fallback) and ref.py (pure-jnp
oracle); tests sweep shapes × dtypes against the oracle in interpret mode.
Which kernels run is a policy decision: ``repro.core.types.local_kernels``
(``REPRO_LOCAL_KERNELS`` — default on for TPU backends, off elsewhere).
"""
