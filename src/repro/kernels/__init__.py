"""Pallas TPU kernels for the paper's compute hot spots.

bitonic/ — local sort + 2-way merge networks (VMEM-resident, VPU-only)
kway/    — Super Scalar Sample Sort k-way classifier with tie-breaking

Each kernel ships ops.py (jit wrapper + fallback) and ref.py (pure-jnp
oracle); tests sweep shapes × dtypes against the oracle in interpret mode.
"""
