"""Pure-numpy oracle for the k-way classifier kernel (u64 composite keys —
numpy is used so the oracle is independent of the jax x64 flag)."""
import jax.numpy as jnp
import numpy as np


def kway_classify_ref(keys, ties, s_keys, s_ties, *, n_buckets: int):
    k = np.asarray(keys).astype(np.uint64)
    t = np.asarray(ties).astype(np.uint64)
    sk = np.asarray(s_keys).astype(np.uint64)
    st = np.asarray(s_ties).astype(np.uint64)
    elem = (k << np.uint64(32)) | t
    spl = (sk << np.uint64(32)) | st
    bucket = np.sum(spl[None, :] <= elem[:, None], axis=1).astype(np.int32)
    hist = np.sum(bucket[:, None] == np.arange(n_buckets)[None, :],
                  axis=0).astype(np.int32)
    return jnp.asarray(bucket), jnp.asarray(hist)
