"""Jitted wrapper for the k-way classifier: pads to the kernel block size,
falls back to the jnp oracle for tiny inputs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import kway
from .kway import BLOCK_R, LANES

_BLOCK = BLOCK_R * LANES


def kway_classify(keys, ties, s_keys, s_ties, *, n_buckets: int,
                  interpret: bool = True, use_kernel: bool = True):
    """Classify u32 (key, tie) pairs against (NB-1,) lex splitters."""
    C = keys.shape[0]
    if not use_kernel or C < _BLOCK:
        from . import ref
        return ref.kway_classify_ref(keys, ties, s_keys, s_ties,
                                     n_buckets=n_buckets)
    pad = (-C) % _BLOCK
    if pad:
        keys = jnp.concatenate([keys, jnp.full((pad,), np.uint32(0xFFFFFFFF),
                                               keys.dtype)])
        ties = jnp.concatenate([ties, jnp.full((pad,), np.uint32(0xFFFFFFFF),
                                               ties.dtype)])
    bucket, hist = kway.kway_classify(keys, ties, s_keys, s_ties,
                                      n_buckets=n_buckets, interpret=interpret)
    if pad:
        # Padded entries are all-ones (key, tie) pairs: every splitter
        # compares <= them, so they land in bucket len(s_keys) — the last
        # bucket only when the caller supplies exactly n_buckets-1
        # splitters.  Subtract them where they actually landed, and clamp:
        # real all-ones elements share that bucket, and the count must
        # never go negative when pad >= the bucket's true population.
        bucket = bucket[:C]
        hist = hist.at[min(s_keys.shape[0], n_buckets - 1)].add(-pad)
        hist = jnp.maximum(hist, 0)
    return bucket, hist
