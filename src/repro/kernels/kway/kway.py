"""Pallas TPU kernel: Super Scalar Sample Sort k-way classifier (paper
App. G) with implicit tie-breaking.

Classifies C elements against up to 127 splitters.  GPU SSSS uses a
branchless binary-search tree; on TPU a *broadcast compare* is the native
formulation: the splitter vector is tiny, so a (block, n_split) outer
comparison runs entirely on the VPU with no gathers and no data-dependent
control flow — one fused pass computes bucket ids and the histogram
(one-hot partial sums accumulated in VMEM across the grid).

Tie-breaking (paper App. G): an element equal to its bounding splitter's
key is re-compared on (pe, pos) — both sides are u32 planes, so the
lexicographic compare is two vector ops.  Element tie info is generated
locally (own PE id / own position); only the splitters carry communicated
tie-break data, keeping the paper's "no per-element overhead" property.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_R = 64                      # 64×128 elements per grid step


def _classify_block(keys, ties, s_keys, s_ties):
    """keys/ties: (R,128) u32; s_keys/s_ties: (S,) u32 → bucket ids (R,128)."""
    k = keys[..., None]                       # (R,128,1)
    t = ties[..., None]
    sk = s_keys[None, None, :]                # (1,1,S)
    st = s_ties[None, None, :]
    le = (sk < k) | ((sk == k) & (st <= t))   # splitter ≤ element (lex)
    # dtype= pins the accumulator: under jax_enable_x64 (flipped on by
    # repro.core) a plain sum promotes to int64 and breaks the i32 ref store
    return jnp.sum(le, axis=-1, dtype=jnp.int32)


def _kway_kernel(keys_ref, ties_ref, sk_ref, st_ref, bucket_ref, hist_ref,
                 *, n_buckets: int):
    i = pl.program_id(0)
    bucket = _classify_block(keys_ref[...], ties_ref[...],
                             sk_ref[...], st_ref[...])
    bucket_ref[...] = bucket
    onehot = (bucket[..., None] ==
              jnp.arange(n_buckets, dtype=jnp.int32)[None, None, :])
    part = jnp.sum(onehot, axis=(0, 1), dtype=jnp.int32)         # (NB,)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += part[None, :]


@functools.partial(jax.jit, static_argnames=("n_buckets", "interpret"))
def kway_classify(keys: jax.Array, ties: jax.Array, s_keys: jax.Array,
                  s_ties: jax.Array, *, n_buckets: int,
                  interpret: bool = True):
    """Returns (bucket_ids (C,), histogram (n_buckets,)).

    C must be a multiple of 64·128 (ops.py pads); splitters are (NB-1,).
    """
    C = keys.shape[0]
    R = C // LANES
    assert C % (BLOCK_R * LANES) == 0
    grid = R // BLOCK_R
    blk = pl.BlockSpec((BLOCK_R, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((s_keys.shape[0],), lambda i: (0,))
    hspec = pl.BlockSpec((1, n_buckets), lambda i: (0, 0))
    bucket, hist = pl.pallas_call(
        functools.partial(_kway_kernel, n_buckets=n_buckets),
        out_shape=(jax.ShapeDtypeStruct((R, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((1, n_buckets), jnp.int32)),
        in_specs=[blk, blk, sspec, sspec],
        out_specs=(blk, hspec),
        grid=(grid,), interpret=interpret,
    )(keys.reshape(R, LANES), ties.reshape(R, LANES), s_keys, s_ties)
    return bucket.reshape(C), hist[0]
