from .ops import kway_classify        # noqa: F401
