"""jnp reference for the fused partition-into-buckets primitive.

This is the semantics contract the Pallas kernel (partition.py) is diffed
against, and the implementation the sim backend / CPU CI actually run.  It
replaces the O(n·nb) one-hot/broadcast formulation that used to live in
``rams._rams_level`` (bucket via ``jnp.sum(splitters[None,:] <= elem[:,None])``,
rank via an nb-wide one-hot ``cumsum``) with O(n·log) primitives:

  * classify: binary-search the nb-1 sorted splitters (SSSS ``#splitters ≤
    elem``, expressed as ``searchsorted(..., side="right")`` — identical
    because the splitter sequence is nondecreasing);
  * rank + histogram: one stable argsort of the bucket ids, then
    first-occurrence subtraction (the ``_alltoall_route`` ranking idiom).

Keys and tie-break tags arrive as separate uint32 planes — the same (hi, lo)
layout the Pallas kernel consumes — and compare lexicographically, which for
(key << 32 | tag) composites equals the u64 compare.

Invalid elements (flat index ≥ ``count``) go to the **trash bucket**
``n_buckets``; they get real ranks there (stable, in flat order) so the
reference and the kernel agree everywhere, but the returned histogram covers
the real buckets only: ``sum(hist) == count``.
"""
from __future__ import annotations

import jax.numpy as jnp


def partition_ref(keys, ties, s_keys, s_ties, *, n_buckets: int,
                  count=None, inclusive: bool = True, want_pos: bool = True):
    """Classify + rank + histogram in one pass (pure jnp).

    Args:
      keys, ties: (C,) uint32 planes of the element composites
        (``key << 32 | tie``); ties may be all-zero when tie-breaking is off.
      s_keys, s_ties: (S,) uint32 planes of the S = n_buckets-1 splitter
        composites, nondecreasing under the (key, tie) lex order.
      n_buckets: number of real buckets; invalid elements land in bucket
        ``n_buckets``.
      count: number of valid elements (prefix of the array), or None for all.
      inclusive: True → bucket = #{s : s ≤ e} (SSSS); False → #{s : s < e}.
      want_pos: skip the rank computation (callers that only need
        bucket/hist, e.g. samplesort's destination map).

    Returns:
      (bucket, pos, hist): bucket (C,) int32 in [0, n_buckets]; pos (C,)
      int32 stable rank within the element's bucket (None when
      ``want_pos=False``); hist (n_buckets,) int32 with
      ``sum(hist) == count``.
    """
    C = keys.shape[0]
    elem = (keys.astype(jnp.uint64) << 32) | ties.astype(jnp.uint64)
    spl = (s_keys.astype(jnp.uint64) << 32) | s_ties.astype(jnp.uint64)
    side = "right" if inclusive else "left"
    bucket = jnp.searchsorted(spl, elem, side=side).astype(jnp.int32)
    if count is not None:
        valid = jnp.arange(C, dtype=jnp.int32) < count
        bucket = jnp.where(valid, bucket, jnp.int32(n_buckets))
    # one stable argsort gives both the histogram (run bounds) and the
    # in-bucket rank (distance to the run start) without any (C, nb) blowup
    order = jnp.argsort(bucket, stable=True)
    sb = bucket[order]
    bounds = jnp.searchsorted(sb, jnp.arange(n_buckets + 1, dtype=jnp.int32),
                              side="left")
    hist = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
    if not want_pos:
        return bucket, None, hist
    first = jnp.searchsorted(sb, sb, side="left")
    rank = jnp.arange(C, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros((C,), jnp.int32).at[order].set(rank)
    return bucket, pos, hist
