"""Fused partition-into-buckets: splitter classification + per-bucket
histogram + stable in-bucket rank in one pass over a locally-sorted shard —
the (bucket, send_pos, hist) triple every all_to_all-based algorithm needs.

``partition_ref`` (ref.py) is the jnp contract; the Pallas TPU kernel lives
in partition.py with the dispatcher in ops.py."""
from .ops import MAX_BUCKETS, partition_buckets  # noqa: F401
from .partition import LANES, partition_tile  # noqa: F401
from .ref import partition_ref  # noqa: F401
