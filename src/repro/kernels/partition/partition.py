"""Pallas TPU kernel: fused splitter classify + histogram + in-bucket rank.

One VMEM-resident pass over a (R, 128) tile does everything the all_to_all
routing needs (the IPS⁴o block-partition shape, arXiv 2009.13569, mapped
onto the VPU):

  * classify: branchless SSSS ``#splitters ≤ elem`` as a lexicographic
    (key, tie) broadcast-compare against the S = nb-1 splitter planes —
    no u64 composites materialize, the two u32 planes compare directly;
  * histogram + stable rank: an (R, 128, nb+1) one-hot is reduced twice —
    ``cumsum`` along lanes + a row-prefix along sublanes give each element
    its stable in-bucket rank in flat (row-major) order, and the column
    sums give the tile histogram.  Elements at flat index ≥ ``nvalid``
    (shard padding) land in the **trash bucket** nb.

The kernel is deliberately ``grid=(1,)`` whole-tile — like kernels/bitonic,
and unlike kernels/kway's ``program_id``-based grid — so it stays correct
under vmap batching (the sim backend wraps every PE body in one vmap; jax
prepends batch dims to the pallas grid, which breaks program_id-relative
offsets but leaves whole-tile launches untouched).  Host code in ops.py
chains tiles by threading the running histogram through successive
launches; ``prev_hist[bucket] + rank_in_tile`` is then the global stable
send position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _partition_kernel(keys_ref, ties_ref, sk_ref, st_ref, ph_ref, nv_ref,
                      bucket_ref, pos_ref, hist_ref, *,
                      n_buckets: int, inclusive: bool):
    R = keys_ref.shape[0]
    nbt = n_buckets + 1
    k = keys_ref[...][..., None]                     # (R, 128, 1)
    t = ties_ref[...][..., None]
    sk = sk_ref[...][None, None, :]                  # (1, 1, S)
    st = st_ref[...][None, None, :]
    if inclusive:                                    # splitter ≤ element?
        le = (sk < k) | ((sk == k) & (st <= t))
    else:                                            # splitter < element?
        le = (sk < k) | ((sk == k) & (st < t))
    bucket = jnp.sum(le, axis=-1, dtype=jnp.int32)   # (R, 128)
    r = jax.lax.broadcasted_iota(jnp.int32, (R, LANES), 0)
    l = jax.lax.broadcasted_iota(jnp.int32, (R, LANES), 1)
    flat = r * LANES + l
    bucket = jnp.where(flat < nv_ref[0, 0], bucket, jnp.int32(n_buckets))
    bucket_ref[...] = bucket

    mask = bucket[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (R, LANES, nbt), 2)
    onehot = mask.astype(jnp.int32)                  # (R, 128, nbt)
    crow = jnp.cumsum(onehot, axis=1, dtype=jnp.int32)   # within-row, incl.
    rowtot = jnp.sum(onehot, axis=1, dtype=jnp.int32)    # (R, nbt)
    rows_before = jnp.cumsum(rowtot, axis=0, dtype=jnp.int32) - rowtot
    prev = ph_ref[...]                               # (1, nbt) running hist
    base = prev[0][None, None, :] + rows_before[:, None, :]
    # select my bucket's column: rank = earlier rows + earlier-in-row + prev
    pos_ref[...] = jnp.sum(jnp.where(mask, base + crow - jnp.int32(1),
                                     jnp.int32(0)), axis=-1, dtype=jnp.int32)
    hist_ref[...] = prev + jnp.sum(rowtot, axis=0, dtype=jnp.int32)[None, :]


@functools.partial(jax.jit,
                   static_argnames=("n_buckets", "inclusive", "interpret"))
def partition_tile(keys2, ties2, s_keys, s_ties, prev_hist, nvalid, *,
                   n_buckets: int, inclusive: bool = True,
                   interpret: bool = True):
    """Partition one (R, 128) tile.  ``prev_hist`` is the (1, nb+1) running
    histogram of earlier tiles (trash bucket included); ``nvalid`` is a
    (1, 1) int32 count of valid elements in this tile (flat order).
    Returns (bucket (R,128), pos (R,128), new_hist (1, nb+1))."""
    R = keys2.shape[0]
    nbt = n_buckets + 1
    blk = pl.BlockSpec((R, LANES), lambda i: (i, 0))
    svec = pl.BlockSpec((n_buckets - 1,), lambda i: (0,))
    hblk = pl.BlockSpec((1, nbt), lambda i: (0, 0))
    one = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kern = functools.partial(_partition_kernel, n_buckets=n_buckets,
                             inclusive=inclusive)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((R, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((R, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((1, nbt), jnp.int32)),
        in_specs=[blk, blk, svec, svec, hblk, one],
        out_specs=(blk, blk, hblk),
        grid=(1,), interpret=interpret)(keys2, ties2, s_keys, s_ties,
                                        prev_hist, nvalid)
