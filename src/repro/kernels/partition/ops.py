"""Dispatcher for the fused partition-into-buckets primitive.

``partition_buckets`` is what the algorithms call (``rams._rams_level``,
``samplesort``'s destination map, ``rquick``'s split point).  It picks the
Pallas tile kernel (partition.py) or the jnp reference (ref.py) — bitwise
identical by tests/test_partition.py — and hides the tiling:

  * the shard is padded to a lane multiple and cut into VMEM-sized tiles
    (tile rows shrink as the bucket count grows: the kernel's working set
    is the (R, 128, nb+1) one-hot);
  * the running histogram threads through the launches, so ranks are
    global over the whole shard exactly like the reference's one argsort.

Kernel-vs-ref selection: an explicit ``use_kernel`` wins; ``None`` defers
to :func:`repro.core.types.local_kernels` (the ``REPRO_LOCAL_KERNELS``
policy — default on for TPU backends, off elsewhere).  The ref handles
every case; the kernel additionally requires uint32 planes, 2 ≤ nb ≤
``MAX_BUCKETS`` and at least one full lane row.
"""
from __future__ import annotations

import jax.numpy as jnp

from .partition import LANES, partition_tile
from .ref import partition_ref

MAX_BUCKETS = 512            # beyond this the one-hot tile no longer fits
_VMEM_WORDS = 1 << 20        # ≈4 MiB budget for one (R, 128, nb+1) i32


def _tile_rows(n_buckets: int) -> int:
    rows = _VMEM_WORDS // (LANES * (n_buckets + 1))
    return max(8, min(64, (rows // 8) * 8))


def partition_buckets(keys, ties, s_keys, s_ties, *, n_buckets: int,
                      count=None, inclusive: bool = True,
                      want_pos: bool = True, interpret: bool = True,
                      use_kernel=None):
    """Fused classify + rank + histogram over a locally-sorted shard.

    Same contract as :func:`repro.kernels.partition.ref.partition_ref`
    (see there for argument semantics); ``use_kernel`` selects the Pallas
    path (None → the ``local_kernels()`` policy)."""
    if use_kernel is None:
        from repro.core.types import local_kernels
        use_kernel = local_kernels().partition
    C = keys.shape[0]
    eligible = (use_kernel and C >= LANES and 2 <= n_buckets <= MAX_BUCKETS
                and keys.dtype == jnp.uint32 and ties.dtype == jnp.uint32
                and s_keys.dtype == jnp.uint32 and s_ties.dtype == jnp.uint32)
    if not eligible:
        return partition_ref(keys, ties, s_keys, s_ties, n_buckets=n_buckets,
                             count=count, inclusive=inclusive,
                             want_pos=want_pos)

    cnt = jnp.asarray(C if count is None else count, jnp.int32)
    pad = (-C) % LANES
    if pad:                     # pad rows classify as trash (flat ≥ nvalid)
        fill = jnp.full((pad,), 0xFFFFFFFF, jnp.uint32)
        keys = jnp.concatenate([keys, fill])
        ties = jnp.concatenate([ties, fill])
    tile = _tile_rows(n_buckets) * LANES
    hist = jnp.zeros((1, n_buckets + 1), jnp.int32)
    buckets, poss = [], []
    off = 0
    total = C + pad
    while off < total:
        step = min(tile, total - off)
        R = step // LANES
        nv = jnp.clip(cnt - off, 0, step).reshape(1, 1)
        b, q, hist = partition_tile(
            keys[off:off + step].reshape(R, LANES),
            ties[off:off + step].reshape(R, LANES),
            s_keys, s_ties, hist, nv,
            n_buckets=n_buckets, inclusive=inclusive, interpret=interpret)
        buckets.append(b.reshape(step))
        poss.append(q.reshape(step))
        off += step
    bucket = jnp.concatenate(buckets)[:C] if len(buckets) > 1 \
        else buckets[0][:C]
    pos = (jnp.concatenate(poss)[:C] if len(poss) > 1 else poss[0][:C]) \
        if want_pos else None
    return bucket, pos, hist[0, :n_buckets]
