"""GatherM and AllGatherM (paper §II / §VII): the very-sparse-input regime.

GatherM: binomial-tree gather-merge — after step t, PEs with t low zero bits
hold the merged data of their 2^t-subcube; PE 0 ends with everything.
AllGatherM: recursive-doubling all-gather-merge — everyone ends with
everything (the building block reused by RFIS rows/columns).

Neither fulfills the balanced-output constraint (paper §VII-A(1)) — the
output lives on PE 0 / on all PEs; ``psort`` accounts for that with a
concentrated output capacity.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from . import comm
from .hypercube import allgather_merge, exchange_shard
from .types import SortShard, local_sort, merge_shards, resize


class GatherResult(NamedTuple):
    shard: SortShard
    overflow: jax.Array


def gather_merge(shard: SortShard, axis_name: str, p: int,
                 dims: Optional[Sequence[int]] = None) -> GatherResult:
    """Binomial-tree gather-merge to PE 0 (lowest PE of the subcube)."""
    dims = list(dims) if dims is not None else list(range(p.bit_length() - 1))
    shard = local_sort(shard)
    me = comm.axis_index(axis_name)
    overflow = jnp.int32(0)
    for t in dims:
        # active senders: PEs whose bits below t are zero and bit t is one
        low_mask = (1 << t) - 1
        is_sender = ((me & low_mask) == 0) & (((me >> t) & 1) == 1)
        cap = shard.capacity
        send = jax.tree.map(
            lambda k: jnp.where(is_sender, k, jnp.zeros_like(k)), shard)
        send = send.replace(count=jnp.where(is_sender, shard.count, 0),
                            keys=jnp.where(is_sender, shard.keys, shard.pad))
        recv = exchange_shard(send, axis_name, p, t)
        keep = shard.replace(count=jnp.where(is_sender, 0, shard.count),
                             keys=jnp.where(is_sender, shard.pad, shard.keys))
        shard, ovf = merge_shards(keep, recv, capacity=2 * cap)
        overflow = overflow + ovf
    return GatherResult(shard, overflow)


def allgather_merge_sort(shard: SortShard, axis_name: str, p: int,
                         dims: Optional[Sequence[int]] = None) -> GatherResult:
    """All-gather-merge: every PE ends with the full sorted input."""
    shard = local_sort(shard)
    out = allgather_merge(shard, axis_name, p, dims=dims)
    return GatherResult(out, jnp.int32(0))
