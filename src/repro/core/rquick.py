"""Robust Quicksort on Hypercubes (paper §VI, Algorithm 2).

Per-iteration structure (dims d-1 .. 0):
  1. splitter = approximate median of the (j+1)-dim subcube, via the
     butterfly window reduction of §III-B (identical on all subcube PEs);
  2. local tie-break split:  a = a_ℓ · s^m · a_r  →  L = a_ℓ·s^x,
     R = s^(m-x)·a_r with x chosen so |L| is closest to |a|/2 — the paper's
     zero-communication duplicate-key defense;
  3. exchange along dim j (0-bit PE keeps the two L's, 1-bit the two R's);
  4. merge with the partner's sequence.

Robustness preconditions: an initial random redistribution (§III-A) turns
worst-case inputs into average-case ones (Lemma 1–3 ⇒ O(1) subcube
imbalance w.h.p.), which is what makes a *fixed* capacity factor sound in
the SPMD/static-shape setting.

``robust=False`` gives NTB-Quick (no shuffle, no tie-breaking) for the
Fig. 2a robustness comparison.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .hypercube import (butterfly_sum, exchange_shard, hypercube_shuffle)
from .median import (butterfly_median_window, lift, splitter_from_window)
from .types import SortShard, compact, local_sort, merge_shards, resize
from repro.kernels.partition import partition_buckets


class RQuickResult(NamedTuple):
    shard: SortShard
    overflow: jax.Array          # elements dropped anywhere (must be 0)


def _split_point(shard: SortShard, splitter_lifted: jax.Array,
                 tie_break: bool) -> jax.Array:
    """Index splitting local sorted data into L=[0,idx) and R=[idx,C).

    With tie-breaking, x ∈ [0, m_eq] is chosen so |L| is closest to m/2.
    Without, all duplicates of the splitter go right (x = 0).
    """
    # fused-partition classify against the single lifted splitter, as
    # (hi, lo) u32 planes; bucket 0 of the inclusive pass holds the
    # elements < s, of the strict pass the elements ≤ s — the histogram
    # counts only valid elements, so no count-clamping is needed
    lifted = lift(shard.keys)
    e_hi = (lifted >> np.uint64(32)).astype(jnp.uint32)
    e_lo = lifted.astype(jnp.uint32)
    s_hi = jnp.reshape(splitter_lifted >> np.uint64(32), (1,)).astype(jnp.uint32)
    s_lo = jnp.reshape(splitter_lifted, (1,)).astype(jnp.uint32)

    def n_below(inclusive):
        _, _, h = partition_buckets(e_hi, e_lo, s_hi, s_lo, n_buckets=2,
                                    count=shard.count, inclusive=inclusive,
                                    want_pos=False)
        return h[0].astype(jnp.int32)

    n_less = n_below(True)             # bucket 0 ⇔ elem < s
    if not tie_break:
        return n_less
    n_leq = n_below(False)             # bucket 0 ⇔ elem ≤ s
    x = jnp.clip(shard.count // 2 - n_less, 0, n_leq - n_less)
    return n_less + x


def rquick(shard: SortShard, axis_name: str, p: int, *,
           seed: int = 0x5EED, window_k: int = 16,
           robust: bool = True, shuffle: Optional[bool] = None,
           tie_break: Optional[bool] = None,
           capacity: Optional[int] = None,
           dims: Optional[Sequence[int]] = None) -> RQuickResult:
    """Sort over the (sub)cube spanned by ``dims`` (default: the whole axis).

    Must be called inside shard_map.  Output: ascending over PE order,
    each shard locally sorted; elements never cross the subcube boundary.
    """
    d_all = p.bit_length() - 1
    dims = list(dims) if dims is not None else list(range(d_all))
    shuffle = robust if shuffle is None else shuffle
    tie_break = robust if tie_break is None else tie_break
    cap = capacity or 2 * shard.capacity
    overflow = jnp.int32(0)

    shard, _ = resize(shard, cap)
    if shuffle:
        shard, ovf = hypercube_shuffle(shard, axis_name, p, seed, dims=dims)
        overflow = overflow + ovf
    shard = local_sort(shard)

    me = comm.axis_index(axis_name)
    for it, j in enumerate(sorted(dims, reverse=True)):
        sub_dims = [t for t in dims if t <= j]
        # --- splitter selection in parallel (§III-B) --------------------
        w = butterfly_median_window(shard, axis_name, p, sub_dims, window_k,
                                    seed=seed * 1000003 + it)
        s, w_empty = splitter_from_window(w, seed=seed * 1000003 + it)
        sub_count = butterfly_sum(shard.count, axis_name, p, sub_dims)
        is_empty = (sub_count == 0) | w_empty

        # --- local tie-break split --------------------------------------
        idx = _split_point(shard, s, tie_break)
        pos = jnp.arange(cap, dtype=jnp.int32)
        i_am_upper = ((me >> j) & 1) == 1
        # lower PE sends R (suffix), upper PE sends L (prefix)
        send_mask = jnp.where(i_am_upper, pos < idx, pos >= idx)
        send_mask = jnp.where(is_empty, jnp.zeros_like(send_mask), send_mask)
        sent = compact(shard, send_mask)
        kept = compact(shard, ~send_mask)
        recv = exchange_shard(sent, axis_name, p, j)
        shard, ovf = merge_shards(kept, recv, capacity=cap)
        overflow = overflow + ovf
    return RQuickResult(shard, overflow)
