"""Out-of-core external sorting: shards larger than device memory.

The paper's claim is robustness across 9 orders of magnitude of n/p, but
in-core ``psort`` caps n/p at device memory.  This module lifts the cap
with the classic run-formation + k-way-merge structure of *Scalable
Distributed-Memory External Sorting* (arXiv 0910.2582), mapped onto the
existing four-layer stack:

  Pass A — run formation.  Each PE's oversized shard lives in **host**
    memory and streams through the device in chunks of ``budget``
    elements: copy-in (``jax.device_put``, double-buffered so chunk r+1
    is in flight while chunk r sorts), device sort by the external total
    order (key, tie), copy-out.  The host owns the run buffers; the
    device only ever holds O(budget) elements.
  Pass B — splitter fit.  The distributed phase runs unchanged on
    *splitter summaries*: each sorted run contributes an every-g-th
    element quantile sketch, one fused ``all_gather`` pools the sketches,
    and the RAMS splitter machinery (``rams.quantile_splitters``) picks
    the p-1 global splitters.  Sketches are tiny, so this is the only
    whole-cohort collective.
  Pass C — per-run exchange.  R = ceil(per/budget) all_to_all passes move
    run *slices* instead of whole shards: pass r classifies run r against
    the global splitters (the ``kernels/kway`` classifier when the local
    kernel policy enables it, a jnp lex compare otherwise) and routes
    through the same slotted ``_alltoall_route`` the in-core algorithms
    use.  Slot capacity is **provisioned from the sketches**: a splitter
    interval holding q of a run's sketch points holds at most (q+2)·g of
    the run's elements (the run-slice capacity invariant, proved in
    docs/ARCHITECTURE.md), so the static slots never overflow.
  Pass D — k-way merge.  Each PE merges its R received (sorted) slices:
    the classifier engine cuts the runs at internal splitters fitted from
    pooled run sketches, streams budget-sized chunks through the device
    sort, and concatenates — chunk intervals are disjoint and ordered, so
    the concatenation is sorted.  A loser-tree host merge
    (``merge="losertree"``) is the reference engine the classifier is
    differential-tested against.

Total order: (key, tie) with tie = ``_mix32(global_index)`` — bijective,
so every element is distinct and duplicate-heavy inputs (Zero, DeterDupl)
split evenly across splitter intervals, exactly the RAMS tie-breaking
argument.  The final key output is tie-independent: it is *the* globally
sorted array, hence bitwise-equal to the in-core path for every
algorithm.

u32 keys ride a u64 composite ``(key << 32) | tie`` through
``SortShard``/``local_sort`` (kernel-policy aware); u64 keys keep
separate (key, tie) planes and sort via ``lexsort`` — the composite would
need 96 bits.

Collectives go through the ambient ``comm`` dispatchers, so
``CountingCollectives`` attributes every pass (tags ``ext:runs``,
``ext:splitters``, ``ext:pass{r}``, ``ext:merge``) and
``FaultyCollectives`` can kill/delay any of them; host↔device copies are
recorded as injected ``ext:h2d`` / ``ext:d2h`` pseudo-events
(:meth:`CommTrace.io_bytes`).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .hypercube import _alltoall_route
from .rams import _mix32, quantile_splitters
from .types import SortShard, local_sort, pad_value

_HI32 = np.uint32(0xFFFFFFFF)
_HI64 = np.uint64(0xFFFFFFFFFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class ExternalPolicy:
    """Out-of-core streaming policy for ``psort(..., external=...)``.

    ``budget`` is the device-resident element budget per PE buffer: shards
    with n/p > budget stream through the device in ceil(n/p / budget)
    runs.  ``sketch_per_run`` sizes the per-run quantile sketch (splitter
    accuracy and exchange-slot provisioning both scale with it).
    ``merge`` picks the pass-D engine: ``"classifier"`` (the kernels/kway
    splitter engine, device-streamed) or ``"losertree"`` (host tournament
    merge — the reference the classifier is tested against).
    ``double_buffer`` overlaps copy-in of chunk r+1 with the device sort
    of chunk r.  ``slot_factor`` scales the sketch-provisioned exchange
    slots (1.0 = the proven bound).
    """

    budget: int
    sketch_per_run: int = 32
    double_buffer: bool = True
    merge: str = "classifier"
    slot_factor: float = 1.0

    def __post_init__(self):
        if self.budget < 1:
            raise ValueError(f"ExternalPolicy.budget must be >= 1, got "
                             f"{self.budget}")
        if self.merge not in ("classifier", "losertree"):
            raise ValueError(f"ExternalPolicy.merge must be 'classifier' or "
                             f"'losertree', got {self.merge!r}")
        if self.sketch_per_run < 1:
            raise ValueError("ExternalPolicy.sketch_per_run must be >= 1")


# ---------------------------------------------------------------------------
# device helpers (module-level jits: cache keyed on (dtype, cap))
# ---------------------------------------------------------------------------


def _sort_planes(k, i, count, *, cap: int):
    """Sort a padded (key, idx) chunk by the external (key, tie) order.

    Returns the (key, tie, idx) planes with the invalid tail at
    (HI, HI32).  The tie plane is derived (``_mix32(idx)``) — it is
    returned so host code never re-implements the mix.  u32 keys route
    the u64 composite through :func:`local_sort` (the kernel policy's
    entry point; the composite is 8 bytes so today's 4-byte bitonic
    kernel declines and the jnp path runs — policy-independent, hence
    safe to cache at module level); u64 keys lexsort their planes.
    """
    pos = jnp.arange(cap, dtype=jnp.int32)
    valid = pos < count
    tie = jnp.where(valid, _mix32(i), _HI32)
    if k.dtype == jnp.uint32:
        c = (k.astype(jnp.uint64) << np.uint64(32)) | tie.astype(jnp.uint64)
        shard = SortShard(keys=jnp.where(valid, c, _HI64),
                          vals={"idx": i}, count=count.astype(jnp.int32))
        shard = local_sort(shard)
        ck = shard.keys
        return ((ck >> np.uint64(32)).astype(jnp.uint32),
                ck.astype(jnp.uint32), shard.vals["idx"])
    km = jnp.where(valid, k, _HI64)
    perm = jnp.lexsort((tie, km))
    return km[perm], tie[perm], i[perm]


# donated (key, idx) buffers: run formation streams budget-sized chunks
# through this, so the device never holds more than the in-flight pair
_device_sort = partial(jax.jit, static_argnames=("cap",),
                       donate_argnums=(0, 1))(_sort_planes)


def _classify_planes(k, t, s_keys, s_ties, nb: int, *, use_kernel: bool):
    """bucket = #splitters lexicographically <= (k, t), in [0, nb-1].

    The kway Pallas kernel runs when the policy enables it, the planes
    are u32, and the block is big enough; otherwise a jnp broadcast lex
    compare (the in-graph fallback — the numpy oracle in kway/ref.py is
    not traceable).  The fallback materializes an (nb-1, C) bool, fine at
    the small splitter counts the external lane uses.
    """
    from repro.kernels.kway import ops as kway_ops
    C = k.shape[0]
    if (use_kernel and k.dtype == jnp.uint32 and t.dtype == jnp.uint32
            and C >= kway_ops._BLOCK and nb >= 2):
        interpret = jax.default_backend() != "tpu"
        bucket, _ = kway_ops.kway_classify(k, t, s_keys, s_ties,
                                           n_buckets=nb, interpret=interpret,
                                           use_kernel=True)
        return bucket.astype(jnp.int32)
    if s_keys.shape[0] == 0:
        return jnp.zeros((C,), jnp.int32)
    le = ((s_keys[:, None] < k[None, :])
          | ((s_keys[:, None] == k[None, :]) & (s_ties[:, None] <= t[None, :])))
    return jnp.sum(le, axis=0).astype(jnp.int32)


@partial(jax.jit, static_argnames=("nb", "use_kernel"))
def _classify_jit(k, t, count, s_keys, s_ties, *, nb: int, use_kernel: bool):
    """Standalone classify with count masking (invalid tail → nb)."""
    bucket = _classify_planes(k, t, s_keys, s_ties, nb, use_kernel=use_kernel)
    return jnp.where(jnp.arange(k.shape[0]) < count, bucket, nb)


# ---------------------------------------------------------------------------
# host-side mirrors (numpy — sketch provisioning and the loser-tree ref)
# ---------------------------------------------------------------------------


def np_bucket(k, t, s_keys, s_ties):
    """Host mirror of :func:`_classify_planes` (lex splitter count)."""
    k, t = np.asarray(k), np.asarray(t)
    s_keys, s_ties = np.asarray(s_keys), np.asarray(s_ties)
    if s_keys.shape[0] == 0:
        return np.zeros(k.shape[0], np.int64)
    le = ((s_keys[:, None] < k[None, :])
          | ((s_keys[:, None] == k[None, :]) & (s_ties[:, None] <= t[None, :])))
    return le.sum(axis=0)


def run_sketch(k, t, s: int):
    """Every-g-th-element quantile sketch of one sorted run.

    g = ceil(L/s), sketch = run[g-1::g] (at most s points; empty run →
    empty sketch).  Returns (sketch_keys, sketch_ties, g).
    """
    k, t = np.asarray(k), np.asarray(t)
    L = k.shape[0]
    g = max(1, -(-L // s))
    return k[g - 1::g], t[g - 1::g], g


def provision(sketch_k, sketch_t, g: int, s_keys, s_ties, nb: int):
    """Per-interval element bound for one run, from its sketch.

    A splitter interval containing q of the run's stride-g sketch points
    contains at most (q+2)·g of the run's elements: a contiguous index
    range with q stride-g points has length <= (q+1)·g - 1 (the run-slice
    capacity invariant).  Returns an (nb,) int array of bounds.
    """
    q = np.zeros(nb, np.int64)
    if len(sketch_k):
        b = np_bucket(sketch_k, sketch_t, s_keys, s_ties)
        np.add.at(q, np.clip(b, 0, nb - 1), 1)
    return (q + 2) * g


def form_runs(keys, idx, *, budget: int, double_buffer: bool = True,
              io=None) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Pass A for one PE: chunk a host-resident shard into sorted runs.

    ``keys``/``idx`` are host arrays of the PE's valid elements (any
    length, including 0 and non-multiples of ``budget``).  Returns
    ``max(1, ceil(len/budget))`` runs of (key, tie, idx) numpy triples,
    each sorted by (key, tie), concatenation a permutation of the input
    (the chunking round-trip property).  ``io(direction, nbytes)`` is
    called around every host↔device copy; with ``double_buffer`` the
    copy-in of chunk r+1 is issued before chunk r's sort is consumed.
    """
    keys, idx = np.asarray(keys), np.asarray(idx)
    n = keys.shape[0]
    B = int(budget)
    R = max(1, -(-n // B))
    note = io if io is not None else (lambda direction, nbytes: None)

    def _put(r):
        lo, hi = r * B, min((r + 1) * B, n)
        kc = np.full(B, pad_value(keys.dtype), keys.dtype)
        ic = np.zeros(B, np.uint32)
        kc[:hi - lo] = keys[lo:hi]
        ic[:hi - lo] = idx[lo:hi]
        note("ext:h2d", kc.nbytes + ic.nbytes)
        return jax.device_put(kc), jax.device_put(ic), hi - lo

    runs = []
    nxt = _put(0)
    for r in range(R):
        kd, id_, cnt = nxt
        if double_buffer and r + 1 < R:
            nxt = _put(r + 1)          # in flight while chunk r sorts
        ks, ts, is_ = _device_sort(kd, id_, jnp.int32(cnt), cap=B)
        ks, ts, is_ = (np.asarray(ks)[:cnt], np.asarray(ts)[:cnt],
                       np.asarray(is_)[:cnt])
        note("ext:d2h", ks.nbytes + ts.nbytes + is_.nbytes)
        runs.append((ks, ts, is_))
        if not double_buffer and r + 1 < R:
            nxt = _put(r + 1)
    return runs


def _losertree_merge(runs):
    """Host k-way tournament merge (binary-heap loser tree) — the
    reference engine ``merge="classifier"`` is differential-tested
    against."""
    kd, td, id_ = runs[0][0].dtype, runs[0][1].dtype, runs[0][2].dtype
    out = list(heapq.merge(*[zip(k.tolist(), t.tolist(), i.tolist())
                             for k, t, i in runs]))
    if not out:
        return (np.zeros(0, kd), np.zeros(0, td), np.zeros(0, id_))
    k, t, i = zip(*out)
    return (np.asarray(k, kd), np.asarray(t, td), np.asarray(i, id_))


def _pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def merge_runs(runs, *, budget: int, merge: str = "classifier",
               sketch_per_run: int = 32, use_kernel: Optional[bool] = None,
               io=None) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pass D for one PE: k-way merge of sorted (key, tie, idx) runs.

    ``"classifier"`` fits ceil(total/budget) - 1 internal splitters from
    the pooled run sketches, cuts every run at them (device classify —
    the kway kernel when the policy allows), and streams the resulting
    interval chunks through the device sort; the chunks are disjoint
    ordered intervals, so their concatenation is the sorted whole.
    ``"losertree"`` merges on the host.  Equal to a lexsort of the
    concatenation either way (the merge property test).
    """
    runs = [r for r in runs if r[0].shape[0]]
    if not runs:
        return (np.zeros(0, np.uint64), np.zeros(0, np.uint32),
                np.zeros(0, np.uint32))
    if merge == "losertree":
        return _losertree_merge(runs)
    if use_kernel is None:
        from .types import local_kernels
        use_kernel = local_kernels().partition
    note = io if io is not None else (lambda direction, nbytes: None)
    total = sum(r[0].shape[0] for r in runs)
    m = max(1, -(-total // int(budget)))
    if len(runs) == 1:
        return runs[0]

    # internal splitters from the pooled sketches (host-side quantiles —
    # an independent schedule, no bitwise constraint with pass B)
    pk = np.concatenate([run_sketch(k, t, sketch_per_run)[0]
                         for k, t, _ in runs])
    pt = np.concatenate([run_sketch(k, t, sketch_per_run)[1]
                         for k, t, _ in runs])
    order = np.lexsort((pt, pk))
    q = (np.arange(1, m, dtype=np.int64) * len(order)) // m
    s_keys = jnp.asarray(pk[order][np.clip(q, 0, len(order) - 1)]) \
        if len(order) else jnp.zeros(0, jnp.dtype(pk.dtype))
    s_ties = jnp.asarray(pt[order][np.clip(q, 0, len(order) - 1)]) \
        if len(order) else jnp.zeros(0, jnp.uint32)
    m = s_keys.shape[0] + 1

    # cut every run at the splitters: device classify, host boundaries
    bounds = []
    for k, t, _ in runs:
        L = k.shape[0]
        cap = _pow2(L)
        kp = np.full(cap, pad_value(k.dtype), k.dtype)
        tp = np.full(cap, _HI32, np.uint32)
        kp[:L], tp[:L] = k, t
        note("ext:h2d", kp.nbytes + tp.nbytes)
        bucket = _classify_jit(jnp.asarray(kp), jnp.asarray(tp),
                               jnp.int32(L), s_keys, s_ties, nb=m,
                               use_kernel=bool(use_kernel))
        bucket = np.asarray(bucket)[:L]
        note("ext:d2h", bucket.nbytes)
        # run is sorted → bucket is nondecreasing → interval j is
        # [bounds[j], bounds[j+1])
        bounds.append(np.concatenate(
            [np.searchsorted(bucket, np.arange(m)), [L]]))

    # stream the interval chunks through the device sort
    chunk_len = [int(sum(b[j + 1] - b[j] for b in bounds))
                 for j in range(m)]
    cap = _pow2(max(chunk_len + [1]))
    out = []
    for j in range(m):
        if chunk_len[j] == 0:
            continue
        kc = np.concatenate([k[b[j]:b[j + 1]]
                             for (k, _, _), b in zip(runs, bounds)])
        ic = np.concatenate([i[b[j]:b[j + 1]]
                             for (_, _, i), b in zip(runs, bounds)])
        L = kc.shape[0]
        kp = np.full(cap, pad_value(kc.dtype), kc.dtype)
        ip = np.zeros(cap, np.uint32)
        kp[:L], ip[:L] = kc, ic
        note("ext:h2d", kp.nbytes + ip.nbytes)
        ks, ts, is_ = _device_sort(jnp.asarray(kp), jnp.asarray(ip),
                                   jnp.int32(L), cap=cap)
        ks, ts, is_ = (np.asarray(ks)[:L], np.asarray(ts)[:L],
                       np.asarray(is_)[:L])
        note("ext:d2h", ks.nbytes + ts.nbytes + is_.nbytes)
        out.append((ks, ts, is_))
    k, t, i = (np.concatenate([o[n] for o in out]) for n in range(3))
    return k, t, i


# ---------------------------------------------------------------------------
# the distributed passes (sim_map bodies) and the driver
# ---------------------------------------------------------------------------


def _fit_splitters(sk, st, *, axis: str, p: int, impl):
    """Pass B: pool the per-PE sketches, pick p-1 global splitters.

    ``sk``/``st`` are (p, S) HI-padded sketch planes.  One fused tiled
    all_gather per plane inside the body (tag ``ext:splitters``); the
    quantile pick is the shared RAMS machinery, so the external schedule
    inherits its robustness argument.  Returns host (p-1,) planes.
    """
    wide = sk.dtype == np.uint64

    def body(ks, ts):
        with comm.tagged("ext:splitters"):
            gk = comm.all_gather(ks, axis, tiled=True)
            gt = comm.all_gather(ts, axis, tiled=True)
        if not wide:
            c = ((gk.astype(jnp.uint64) << np.uint64(32))
                 | gt.astype(jnp.uint64))
            spl = quantile_splitters(jnp.sort(c), p)
            return ((spl >> np.uint64(32)).astype(jnp.uint32),
                    spl.astype(jnp.uint32))
        perm = jnp.lexsort((gt, gk))
        gk, gt = gk[perm], gt[perm]
        n_valid = jnp.sum(~((gk == _HI64) & (gt == _HI32)))
        q = (jnp.arange(1, p, dtype=jnp.int64) * n_valid) // p
        q = jnp.clip(q, 0, gk.shape[0] - 1)
        return gk[q], gt[q]

    runner = comm.sim_map(body, axis, p, impl=impl)
    out_k, out_t = jax.jit(runner)(jnp.asarray(sk), jnp.asarray(st))
    return np.asarray(out_k[0]), np.asarray(out_t[0])


def _exchange_pass(kr, ir, counts, s_keys, s_ties, *, axis: str, p: int,
                   slot_cap: int, impl, tag: str, use_kernel: bool,
                   overlap: bool = False):
    """Pass C, one run index: classify against the global splitters and
    route the run slices through one slotted all_to_all; each PE sorts
    what it received.  Returns host (p, p*slot_cap) sorted planes,
    (p,) counts, (p,) overflow.

    ``overlap=True`` streams the route (``_alltoall_route(stream=True)``);
    u32 keys then skip the post-exchange :func:`_sort_planes` entirely —
    the streamed merge folds by the u64 (key, tie) composite, so the
    received buffer already *is* the sorted planes.  u64 keys keep the
    re-sort: their tie plane does not travel through the route, and the
    recomputed (key, tie) lexsort is bitwise-identical either way because
    ties are globally unique.
    """
    cap = kr.shape[1]
    sk_c, st_c = jnp.asarray(s_keys), jnp.asarray(s_ties)
    wide = kr.dtype == np.uint64

    def body(k, i, c):
        with comm.tagged(tag):
            pos = jnp.arange(cap, dtype=jnp.int32)
            valid = pos < c
            tie = jnp.where(valid, _mix32(i), _HI32)
            bucket = _classify_planes(k, tie, sk_c, st_c, p,
                                      use_kernel=use_kernel)
            dest = jnp.where(valid, bucket, p)
            if not wide:
                keys = jnp.where(
                    valid,
                    (k.astype(jnp.uint64) << np.uint64(32))
                    | tie.astype(jnp.uint64), _HI64)
            else:
                keys = jnp.where(valid, k, _HI64)
            shard = SortShard(keys=keys, vals={"idx": i},
                              count=c.astype(jnp.int32))
            out, ovf = _alltoall_route(shard, dest, axis, p, slot_cap,
                                       stream=overlap)
        if overlap and not wide:
            ck = out.keys                     # sorted u64 composite
            return ((ck >> np.uint64(32)).astype(jnp.uint32),
                    ck.astype(jnp.uint32), out.vals["idx"], out.count, ovf)
        ko, to, io_ = _sort_planes(
            (out.keys >> np.uint64(32)).astype(jnp.uint32) if not wide
            else out.keys,
            out.vals["idx"], out.count, cap=out.capacity)
        return ko, to, io_, out.count, ovf

    runner = comm.sim_map(body, axis, p, impl=impl)
    k, t, i, c, o = jax.jit(runner)(jnp.asarray(kr), jnp.asarray(ir),
                                    jnp.asarray(counts, jnp.int32))
    return (np.asarray(k), np.asarray(t), np.asarray(i),
            np.asarray(c), np.asarray(o))


def _merge_barrier(counts, *, axis: str, p: int, impl):
    """Pass D's one collective: psum the per-PE received totals before the
    host merges (tag ``ext:merge`` — the fault lane's merge-pass target).
    Returns the global total.
    """
    def body(c):
        with comm.tagged("ext:merge"):
            return comm.psum(c, axis)

    runner = comm.sim_map(body, axis, p, impl=impl)
    out = jax.jit(runner)(jnp.asarray(counts, jnp.int64))
    return int(np.asarray(out)[0])


def _io_recorder(impl, tag: str, pe: Optional[int] = None):
    """ext:h2d / ext:d2h pseudo-event recorder bound to the active trace
    (CountingCollectives / FaultyCollectives expose ``.trace``; plain
    backends record nothing)."""
    cur = impl if impl is not None else comm.current()
    tr = getattr(cur, "trace", None)
    if tr is None:
        return None
    return lambda direction, nbytes: tr.add(direction, int(nbytes), 1,
                                            tag=tag, pe=pe)


def _psort_external_once(u, n: int, *, axis: str, p: int,
                         policy: ExternalPolicy, impl=None,
                         overlap: bool = False):
    """Run the four external passes once at the current topology.

    ``u`` is the full uint key array (host or device); returns host
    ``(keys (1, p, out_cap), idx (1, p, out_cap), counts (1, p),
    overflow (1, p))`` — the same contract as ``_psort_sim_once``, so the
    fault driver's exclude-and-rescale loop composes unchanged.  Raises
    :class:`comm.PEFailure` at trace time under a matching fault plan.

    ``overlap=True`` pipelines both ends of pass C: each slotted exchange
    streams through ``comm.alltoall_stream``, and every received slice is
    folded into a per-PE running merge (``merge_runs``, the kway-kernel
    classifier engine when eligible) as soon as its pass lands, so pass D
    finds the merge already done.  Bitwise-identical: ties are bijective
    in the global index, so any merge order yields the same planes.
    """
    u = np.asarray(u)
    per = -(-max(n, 1) // p)
    B = int(policy.budget)
    R = max(1, -(-per // B))
    s = int(policy.sketch_per_run)
    from .types import local_kernels
    use_kernel = local_kernels().partition
    counts = np.minimum(np.maximum(n - per * np.arange(p), 0),
                        per).astype(np.int64)

    # --- pass A: run formation (host → device → host, per PE) -------------
    io_runs = _io_recorder(impl, "ext:runs")
    runs = []
    for pe in range(p):
        lo = pe * per
        ke = u[lo:lo + counts[pe]]
        ie = (lo + np.arange(counts[pe])).astype(np.uint32)
        runs.append(form_runs(ke, ie, budget=B,
                              double_buffer=policy.double_buffer,
                              io=io_runs))

    # --- pass B: splitter fit on the run sketches -------------------------
    S = R * s
    hi_k = pad_value(u.dtype)
    sk = np.full((p, S), hi_k, u.dtype)
    st = np.full((p, S), _HI32, np.uint32)
    gs = np.ones((p, R), np.int64)
    sklen = np.zeros((p, R), np.int64)
    for pe in range(p):
        for r, (k, t, _) in enumerate(runs[pe]):
            qk, qt, g = run_sketch(k, t, s)
            sk[pe, r * s:r * s + len(qk)] = qk
            st[pe, r * s:r * s + len(qk)] = qt
            gs[pe, r], sklen[pe, r] = g, len(qk)
    s_keys, s_ties = _fit_splitters(sk, st, axis=axis, p=p, impl=impl)

    # --- pass C: per-run slotted exchanges --------------------------------
    received = [[] for _ in range(p)]
    acc: List[Optional[Tuple]] = [None] * p   # overlap: running merge per PE
    recv_counts = np.zeros(p, np.int64)
    overflow = np.zeros(p, np.int64)
    io_merge = _io_recorder(impl, "ext:merge")
    for r in range(R):
        # provision the slot from the sketches (the capacity invariant)
        cap_rd = max(
            int(provision(sk[pe, r * s:r * s + sklen[pe, r]],
                          st[pe, r * s:r * s + sklen[pe, r]],
                          int(gs[pe, r]), s_keys, s_ties, p).max())
            for pe in range(p))
        slot_cap = max(4, int(math.ceil(policy.slot_factor * cap_rd)))
        kr = np.full((p, B), hi_k, u.dtype)
        ir = np.zeros((p, B), np.uint32)
        cr = np.zeros(p, np.int32)
        for pe in range(p):
            if r < len(runs[pe]):
                k, _, i = runs[pe][r]
                kr[pe, :len(k)], ir[pe, :len(k)], cr[pe] = k, i, len(k)
        ko, to, io_, co, oo = _exchange_pass(
            kr, ir, cr, s_keys, s_ties, axis=axis, p=p, slot_cap=slot_cap,
            impl=impl, tag=f"ext:pass{r}", use_kernel=use_kernel,
            overlap=overlap)
        overflow += np.asarray(oo, np.int64)
        for pe in range(p):
            c = int(co[pe])
            recv_counts[pe] += c
            sl = (ko[pe, :c], to[pe, :c], io_[pe, :c])
            if overlap:
                # fold the slice into the running merge while pass r+1's
                # exchange is still ahead — pass D's merge is then a no-op
                acc[pe] = sl if acc[pe] is None else merge_runs(
                    [acc[pe], sl], budget=B, merge=policy.merge,
                    sketch_per_run=s, use_kernel=use_kernel, io=io_merge)
            else:
                received[pe].append(sl)

    # --- pass D: merge barrier + per-PE k-way merge -----------------------
    _merge_barrier(recv_counts, axis=axis, p=p, impl=impl)
    if overlap:
        empty = (np.zeros(0, u.dtype), np.zeros(0, np.uint32),
                 np.zeros(0, np.uint32))
        merged = [acc[pe] if acc[pe] is not None else empty
                  for pe in range(p)]
    else:
        merged = [merge_runs(received[pe], budget=B, merge=policy.merge,
                             sketch_per_run=s, use_kernel=use_kernel,
                             io=io_merge)
                  for pe in range(p)]

    out_counts = np.array([len(m[0]) for m in merged], np.int32)
    out_cap = max(4, int(out_counts.max(initial=1)))
    k_out = np.full((1, p, out_cap), hi_k, u.dtype)
    i_out = np.zeros((1, p, out_cap), np.uint32)
    for pe in range(p):
        c = out_counts[pe]
        k_out[0, pe, :c] = merged[pe][0]
        i_out[0, pe, :c] = merged[pe][2]
    return (k_out, i_out, out_counts.reshape(1, p),
            overflow.astype(np.int32).reshape(1, p))
