"""Collectives runtime: one interface, two execution backends.

Every communication primitive the sorting library uses (``ppermute``,
``psum``, ``all_gather``, ``all_to_all``, ``axis_index`` and their grouped
variants) is routed through the module-level functions below, which dispatch
to the *current* :class:`Collectives` implementation:

  * :class:`LaxCollectives` — the production path: thin forwarding to
    ``jax.lax``; valid inside ``shard_map`` over real (or emulated host)
    devices.  This is the default.

  * :class:`SimCollectives` — the **simulation backend**: the same algorithm
    bodies are evaluated over a leading PE axis in a single process with
    ``jax.vmap(body, axis_name=...)`` (see :func:`sim_map`).  vmap's
    batching rules implement the ungrouped collectives natively; the grouped
    variants (``axis_index_groups``), which vmap does not support, are
    implemented here from one full ``all_gather`` plus static group-index
    tables.  This lifts the XLA host-device cap: ``psort`` and the hypercube
    primitives run at p = 64–1024 emulated PEs in one process, enough to
    exercise the paper's p-scaling behavior in CI.

Backends are scoped with :func:`use` (a context manager); the scope must be
active while the algorithm body is *traced*, so backend runners like
:func:`sim_map` enter it inside their traced wrapper.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Collectives:
    """Interface of the named-axis collectives the library relies on."""

    name = "abstract"

    def axis_index(self, axis_name):
        raise NotImplementedError

    def ppermute(self, x, axis_name, perm):
        raise NotImplementedError

    def psum(self, x, axis_name, axis_index_groups=None):
        raise NotImplementedError

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        raise NotImplementedError

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        raise NotImplementedError


class LaxCollectives(Collectives):
    """Forward to ``jax.lax`` — the shard_map / real-device path."""

    name = "shard_map"

    def axis_index(self, axis_name):
        return jax.lax.axis_index(axis_name)

    def ppermute(self, x, axis_name, perm):
        return jax.lax.ppermute(x, axis_name, perm)

    def psum(self, x, axis_name, axis_index_groups=None):
        return jax.lax.psum(x, axis_name, axis_index_groups=axis_index_groups)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        return jax.lax.all_gather(x, axis_name,
                                  axis_index_groups=axis_index_groups,
                                  tiled=tiled)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                                  concat_axis=concat_axis,
                                  axis_index_groups=axis_index_groups,
                                  tiled=tiled)


def _group_tables(axis_index_groups):
    """Static lookup tables for grouped collectives.

    Returns (members, rank): ``members[i]`` lists the PEs of i's group in
    group order; ``rank[i]`` is i's position within its group.  Groups must
    partition the axis and share one size (the jax.lax contract).
    """
    groups = [list(g) for g in axis_index_groups]
    size = len(groups[0])
    assert all(len(g) == size for g in groups), "groups must be equal-sized"
    p = sum(len(g) for g in groups)
    assert sorted(pe for g in groups for pe in g) == list(range(p)), \
        "groups must partition the axis"
    members = np.zeros((p, size), np.int32)
    rank = np.zeros((p,), np.int32)
    for g in groups:
        for r, pe in enumerate(g):
            members[pe] = g
            rank[pe] = r
    return members, rank


class SimCollectives(Collectives):
    """Collectives valid under ``jax.vmap(..., axis_name=...)``.

    Ungrouped primitives delegate to ``jax.lax`` (vmap has batching rules
    for them with semantics identical to shard_map's).  Grouped variants are
    built from one full all_gather + static index tables, because vmap's
    collective batching rejects ``axis_index_groups``.
    """

    name = "sim"

    def axis_index(self, axis_name):
        return jax.lax.axis_index(axis_name)

    def ppermute(self, x, axis_name, perm):
        return jax.lax.ppermute(x, axis_name, perm)

    def psum(self, x, axis_name, axis_index_groups=None):
        if axis_index_groups is None:
            return jax.lax.psum(x, axis_name)
        members, _ = _group_tables(axis_index_groups)

        def one(v):
            g = jax.lax.all_gather(v, axis_name)          # (p, ...)
            mine = jnp.take(jnp.asarray(members),
                            jax.lax.axis_index(axis_name), axis=0)
            # dtype= matches lax.psum's dtype-preserving contract (a bare
            # sum promotes int32 → int64 under jax_enable_x64)
            return jnp.sum(jnp.take(g, mine, axis=0), axis=0, dtype=v.dtype)

        return jax.tree.map(one, x)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        if axis_index_groups is None:
            return jax.lax.all_gather(x, axis_name, tiled=tiled)
        members, _ = _group_tables(axis_index_groups)

        def one(v):
            g = jax.lax.all_gather(v, axis_name)          # (p, ...)
            mine = jnp.take(jnp.asarray(members),
                            jax.lax.axis_index(axis_name), axis=0)
            out = jnp.take(g, mine, axis=0)               # (gsize, ...)
            if tiled:
                out = out.reshape((-1,) + out.shape[2:])
            return out

        return jax.tree.map(one, x)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        if axis_index_groups is None:
            return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                                      concat_axis=concat_axis, tiled=tiled)
        if split_axis != 0 or concat_axis != 0 or not tiled:
            raise NotImplementedError(
                "sim grouped all_to_all supports tiled split/concat axis 0")
        members, rank = _group_tables(axis_index_groups)
        gsize = members.shape[1]

        def one(v):
            assert v.shape[0] % gsize == 0, (v.shape, gsize)
            blk = v.shape[0] // gsize
            g = jax.lax.all_gather(v, axis_name)          # (p, gsize*blk, ...)
            me = jax.lax.axis_index(axis_name)
            mine = jnp.take(jnp.asarray(members), me, axis=0)
            r = jnp.take(jnp.asarray(rank), me)
            sel = jnp.take(g, mine, axis=0)               # (gsize, gsize*blk, ...)
            out = jax.lax.dynamic_slice_in_dim(sel, r * blk, blk, axis=1)
            return out.reshape((-1,) + out.shape[2:])     # (gsize*blk, ...)

        return jax.tree.map(one, x)


LAX = LaxCollectives()
SIM = SimCollectives()

# ContextVar, not a module global: tracing may happen from several threads
# (e.g. two jit cache misses racing), and each trace must see its own
# backend scope.
_CURRENT: contextvars.ContextVar[Collectives] = contextvars.ContextVar(
    "repro_collectives", default=LAX)


def current() -> Collectives:
    return _CURRENT.get()


@contextlib.contextmanager
def use(impl: Collectives):
    """Scope the active collectives backend (around *tracing*)."""
    token = _CURRENT.set(impl)
    try:
        yield impl
    finally:
        _CURRENT.reset(token)


# --- module-level dispatchers: the call-site API ---------------------------


def axis_index(axis_name):
    return _CURRENT.get().axis_index(axis_name)


def ppermute(x, axis_name, perm):
    return _CURRENT.get().ppermute(x, axis_name, perm)


def psum(x, axis_name, axis_index_groups=None):
    return _CURRENT.get().psum(x, axis_name,
                               axis_index_groups=axis_index_groups)


def all_gather(x, axis_name, axis_index_groups=None, tiled=False):
    return _CURRENT.get().all_gather(x, axis_name,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)


def all_to_all(x, axis_name, split_axis=0, concat_axis=0,
               axis_index_groups=None, tiled=False):
    return _CURRENT.get().all_to_all(x, axis_name, split_axis=split_axis,
                                     concat_axis=concat_axis,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)


# --- simulation runner -----------------------------------------------------


def sim_map(body, axis_name: str, p: Optional[int] = None):
    """Run a per-PE SPMD ``body`` over a leading PE axis in one process.

    ``body`` is the same function one would pass to ``shard_map`` minus the
    leading block dimension: inputs/outputs are per-PE values, batched over
    axis 0 of the arguments.  Collectives inside the body must go through
    this module; they dispatch to :data:`SIM` while the body is traced.
    """

    def run(*args):
        if p is not None:
            for a in jax.tree.leaves(args):
                assert a.shape[0] == p, (a.shape, p)
        with use(SIM):
            return jax.vmap(body, axis_name=axis_name)(*args)

    return run
