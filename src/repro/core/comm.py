"""Collectives runtime: one interface, two execution backends, one decorator.

Every communication primitive the sorting library uses (``ppermute``,
``psum``, ``all_gather``, ``all_to_all``, ``axis_index`` and their grouped
variants) is routed through the module-level functions below, which dispatch
to the *current* :class:`Collectives` implementation:

  * :class:`LaxCollectives` — the production path: thin forwarding to
    ``jax.lax``; valid inside ``shard_map`` over real (or emulated host)
    devices.  This is the default.

  * :class:`SimCollectives` — the **simulation backend**: the same algorithm
    bodies are evaluated over a leading PE axis in a single process with
    ``jax.vmap(body, axis_name=...)`` (see :func:`sim_map`).  vmap's
    batching rules implement the ungrouped collectives natively; the grouped
    variants (``axis_index_groups``), which vmap does not support, are
    implemented here from static group-index tables.  Small groups use one
    full ``all_gather`` + table lookup; once the batched gather buffer would
    exceed ``chunk_bytes`` (the p² blow-up that kept the sim backend under
    p = 256), the same result is produced *chunked*: a ``lax.scan`` ring of
    ``ppermute`` steps moves one PE block per iteration, so peak memory is
    the output size O(p·g) instead of O(p²).  This lifts the sim backend to
    p = 1024 emulated PEs in one process.  :func:`sim_map` also has a
    ``mesh=(d, p)`` mode emulating a 2-D (data × sort) device mesh: the
    data axis is an outer vmap, and every collective resolves within the
    row's p-sized sort subgroup.

  * :class:`CountingCollectives` — a decorator backend: wraps any
    ``Collectives``, forwards every call unchanged, and records a structured
    :class:`CommTrace` (per-primitive launch counts, payload bytes per PE,
    group sizes, target axis, phase tag).  ``benchmarks/calibrate.py`` fits
    the machine profile of ``core/selection.py`` from these traces;
    :func:`counting` scopes one.

  * :class:`FaultyCollectives` — a decorator backend (mirroring
    :class:`CountingCollectives`) that executes a deterministic
    :class:`FaultPlan` while the body is traced: a planned *kill* raises a
    structured :class:`PEFailure` at the first collective of the matching
    phase tag (the way a dead participant aborts a fused collective for
    its whole group), a planned *delay* records a stretched simulated step
    time for the watchdog lane.  Composable with :class:`SimCollectives`
    and :class:`CountingCollectives`; injected events are recorded into
    the same :class:`CommTrace` (``fault:kill`` / ``fault:delay``
    pseudo-primitives carrying the target PE, axis and phase tag), which
    is what lets the fault tests assert *where* a fault fired and that the
    rescaled re-run followed (see ``psort(fault_policy=...)`` in
    ``core/api.py``).

  * :class:`NestedCollectives` — a decorator *view*: presents one virtual
    flat axis over an ``(outer, inner)`` pair of real named axes (a
    hierarchical inter-host × intra-host mesh) and decomposes every
    virtual-axis collective element-exactly onto the real axes of the
    wrapped backend — so the unchanged algorithm bodies run over nested
    meshes, bitwise-identical to the flat-axis path, on both the Lax and
    Sim backends (:func:`nested` scopes the shard_map side;
    ``sim_map(nested=...)`` the simulated side).

Backends are scoped with :func:`use` (a context manager); the scope must be
active while the algorithm body is *traced*, so backend runners like
:func:`sim_map` enter it inside their traced wrapper.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Collectives:
    """Interface of the named-axis collectives the library relies on.

    Every method takes an ``axis_name`` and resolves **relative to that
    named axis only** — never to the full device set.  On a multi-axis
    mesh (say ``("data", "sort")``), ``axis_index(x, "sort")`` is the
    position *within* the sort axis, ``all_gather(x, "sort")`` gathers the
    ``mesh.shape["sort"]`` participants that share this PE's data-axis
    coordinate, and ``axis_index_groups`` lists indices *along the named
    axis* (per the ``jax.lax`` contract), so one grouped collective runs
    independently inside every subgroup of every data-axis slice.  This is
    what lets the sorting algorithms — which only ever receive
    ``(axis_name, p)`` with ``p`` = the sort-axis size — run unchanged
    within named subgroups of a 2-D mesh (see :func:`sim_map`'s ``mesh=``
    mode and ``psort`` on batched inputs).

    Implementations:

    * :class:`LaxCollectives` — forwards to ``jax.lax`` (named-axis
      resolution is the ``shard_map`` semantics);
    * :class:`SimCollectives` — the same semantics under ``jax.vmap``
      with grouped variants built from static tables;
    * :class:`CountingCollectives` — forwards to another backend and
      records a :class:`CommTrace`.
    """

    name = "abstract"

    def axis_index(self, axis_name):
        raise NotImplementedError

    def ppermute(self, x, axis_name, perm):
        raise NotImplementedError

    def psum(self, x, axis_name, axis_index_groups=None):
        raise NotImplementedError

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        raise NotImplementedError

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        raise NotImplementedError

    def alltoall_stream(self, x, axis_name, fold, init, gsize,
                        axis_index_groups=None):
        """Chunk-granular all_to_all: fold per-source blocks as they arrive.

        ``x`` is a pytree of tiled per-destination buffers — every leaf has
        ``shape[0]`` divisible by ``gsize``, laid out exactly like the input
        of ``all_to_all(split_axis=0, concat_axis=0, tiled=True)``.  Instead
        of returning the gathered buffer, the received data is delivered one
        *source block* at a time: ``fold(carry, chunk, src)`` consumes the
        block sent by group member ``src`` (a traced int32 group rank;
        ``chunk`` leaves have shape ``(shape[0] // gsize, ...)``) and returns
        the updated carry.  Returns the final carry.

        Delivery-order contract: every source is delivered exactly once;
        sources in ``[0, my_rank)`` arrive in ascending order, as do sources
        in ``[my_rank, gsize)`` — the interleaving of the two runs is
        implementation-defined (the ring implementations start at own rank
        and wrap, the barrier fallback folds ``0..gsize-1``).  Consumers
        must therefore be insensitive to the interleaving; the two-run
        incremental merge in ``hypercube._alltoall_route(stream=True)`` is
        the canonical such fold.

        This default implementation is the *barrier* fallback: one regular
        ``all_to_all``, then the blocks folded in ascending source order —
        bitwise-identical to any conforming streaming implementation, and
        inherited by backends without a chunked path (e.g.
        :class:`NestedCollectives`).
        """
        recv = self.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                               axis_index_groups=axis_index_groups,
                               tiled=True)
        carry = init
        for s in range(gsize):
            chunk = jax.tree.map(
                lambda v, s=s: v[s * (v.shape[0] // gsize):
                                 (s + 1) * (v.shape[0] // gsize)], recv)
            carry = fold(carry, chunk, jnp.int32(s))
        return carry

    def _stream_ring(self, x, axis_name, fold, init, gsize,
                     axis_index_groups=None):
        """Shared ring-scan ``alltoall_stream``: a ``lax.scan`` carries the
        rotating send buffer (one ``ppermute`` per step, exactly the chunked
        ring of ``SimCollectives``), and each step folds the block that just
        arrived — at step t my block of group member (rank + t) mod g.  Used
        by :class:`LaxCollectives` and :class:`SimCollectives`; delivery
        starts at own rank and wraps, satisfying the two-ascending-runs
        contract."""
        for v in jax.tree.leaves(x):
            assert v.shape[0] % gsize == 0, (v.shape, gsize)
        if axis_index_groups is None or \
                _is_full_identity_group(axis_index_groups):
            perm = [((i + 1) % gsize, i) for i in range(gsize)]
            r = self.axis_index(axis_name).astype(jnp.int32)
        else:
            members, rank = _group_tables(axis_index_groups)
            assert members.shape[1] == gsize, (members.shape, gsize)
            perm = _ring_perm(members, rank)
            r = jnp.take(jnp.asarray(rank),
                         self.axis_index(axis_name)).astype(jnp.int32)

        def slice_mine(v):
            blk = v.shape[0] // gsize
            return jax.lax.dynamic_slice_in_dim(v, r * blk, blk, axis=0)

        def step(carry, t):
            buf, acc = carry
            chunk = jax.tree.map(slice_mine, buf)
            acc = fold(acc, chunk, ((r + t) % gsize).astype(jnp.int32))
            buf = jax.tree.map(
                lambda v: self.ppermute(v, axis_name, perm), buf)
            return (buf, acc), None

        (_, acc), _ = jax.lax.scan(step, (x, init),
                                   jnp.arange(gsize, dtype=jnp.int32))
        return acc


class LaxCollectives(Collectives):
    """Forward to ``jax.lax`` — the shard_map / real-device path."""

    name = "shard_map"

    def axis_index(self, axis_name):
        return jax.lax.axis_index(axis_name)

    def ppermute(self, x, axis_name, perm):
        return jax.lax.ppermute(x, axis_name, perm)

    def psum(self, x, axis_name, axis_index_groups=None):
        return jax.lax.psum(x, axis_name, axis_index_groups=axis_index_groups)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        return jax.lax.all_gather(x, axis_name,
                                  axis_index_groups=axis_index_groups,
                                  tiled=tiled)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                                  concat_axis=concat_axis,
                                  axis_index_groups=axis_index_groups,
                                  tiled=tiled)

    def alltoall_stream(self, x, axis_name, fold, init, gsize,
                        axis_index_groups=None):
        # lax.scan carries the rotating buffer; one ppermute per step.
        return self._stream_ring(x, axis_name, fold, init, gsize,
                                 axis_index_groups=axis_index_groups)


# ---------------------------------------------------------------------------
# Instrumentation: CommTrace + CountingCollectives
# ---------------------------------------------------------------------------


def _payload_bytes(x) -> int:
    """Static per-PE payload size of a pytree (works on tracers)."""
    total = 0
    for leaf in jax.tree.leaves(x):
        shape = jnp.shape(leaf)
        dtype = np.dtype(jnp.result_type(leaf))
        total += int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return total


@dataclasses.dataclass(frozen=True)
class CommEvent:
    """One collective launch as seen at the call site (per PE).

    ``primitive`` is one of the four collectives for regular launches;
    fault-lane records use the pseudo-primitives ``fault:kill`` /
    ``fault:delay`` (:class:`FaultyCollectives`) and ``rescale`` (the
    ``psort`` fault driver, with ``group_size`` = the post-rescale p).
    ``pe`` identifies the PE an injected event targeted (regular launches
    leave it ``None`` — the trace is per-PE already).
    """
    primitive: str                    # ppermute | psum | all_gather | all_to_all
    bytes: int                        # payload bytes moved per PE (input side)
    group_size: Optional[int] = None  # participants; None = the full axis
    axis: Optional[str] = None        # mesh axis the launch targeted
    tag: Optional[str] = None         # algorithm phase (see :func:`tagged`)
    pe: Optional[int] = None          # target PE of an injected fault event


class CommTrace:
    """Structured record of every collective launched while tracing a body.

    The counts are *trace-time* quantities: one event per call site
    execution, with payload sizes read off the static shapes.  Unrolled
    loops therefore contribute one event per iteration — exactly the launch
    count the α-terms of the cost model charge for.

    Each event carries the mesh axis it targeted and the active phase tag
    (:func:`tagged` — RAMS labels its shuffle and every level).  Under a
    :class:`NestedCollectives` view the recorded axes are the *real* mesh
    axes of the decomposed launches, so :meth:`by_axis` splits inter- from
    intra-axis volume and :meth:`by_tag` attributes it per level.
    """

    def __init__(self):
        self.events: List[CommEvent] = []

    def add(self, primitive: str, nbytes: int,
            group_size: Optional[int] = None, axis: Optional[str] = None,
            tag: Optional[str] = None, pe: Optional[int] = None):
        self.events.append(CommEvent(primitive, int(nbytes), group_size,
                                     axis, tag, pe))

    # -- aggregation ------------------------------------------------------

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.primitive] = out.get(e.primitive, 0) + 1
        return out

    def payload_bytes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.primitive] = out.get(e.primitive, 0) + e.bytes
        return out

    PRIMITIVES = ("ppermute", "psum", "all_gather", "all_to_all")

    def injected(self) -> List[CommEvent]:
        """Injected fault-lane records (``fault:*`` / ``rescale``) — kept
        out of every launch/byte aggregate so a faulted trace still fits
        the cost model; the fault tests read them directly."""
        return [e for e in self.events if e.primitive not in self.PRIMITIVES]

    @property
    def launches(self) -> int:
        return sum(1 for e in self.events if e.primitive in self.PRIMITIVES)

    @property
    def p2p_launches(self) -> int:
        """Point-to-point steps (collective-permutes) — the α term."""
        return sum(1 for e in self.events if e.primitive == "ppermute")

    @property
    def fused_launches(self) -> int:
        """Hardware-routed fused collectives — the α_c term."""
        return self.launches - self.p2p_launches

    def fused_hops(self, p: int) -> float:
        """Σ over fused launches of the torus pipeline depth (group p)^⅓ —
        the α_hop term of the v5e-style model in ``core/selection.py``."""
        return float(sum((e.group_size or p) ** (1.0 / 3.0)
                         for e in self.events
                         if e.primitive in self.PRIMITIVES
                         and e.primitive != "ppermute"))

    IO_PRIMITIVES = ("ext:h2d", "ext:d2h")

    def wire_bytes(self) -> int:
        # injected events (fault records, external-lane I/O) never count
        # toward the on-wire volume the cost model's beta is fitted from
        return sum(e.bytes for e in self.events
                   if e.primitive in self.PRIMITIVES)

    def io_bytes(self) -> int:
        """Host↔device streaming volume of the external lane — the
        ``ext:h2d`` / ``ext:d2h`` pseudo-events the out-of-core driver
        injects around its copies (they are not collectives, so they stay
        out of :attr:`launches` / :meth:`wire_bytes`; the ``io_beta`` cost
        term is fitted against this aggregate)."""
        return sum(e.bytes for e in self.events
                   if e.primitive in self.IO_PRIMITIVES)

    # -- axis / phase attribution ----------------------------------------

    def filter(self, primitive: Optional[str] = None,
               axis: Optional[str] = None,
               tag: Optional[str] = None) -> "CommTrace":
        """Sub-trace of the events matching every given criterion
        (``None`` criteria are ignored; ``axis=""`` / ``tag=""`` select
        events with the field unset)."""
        sub = CommTrace()
        for e in self.events:
            if primitive is not None and e.primitive != primitive:
                continue
            if axis is not None and (e.axis or "") != axis:
                continue
            if tag is not None and (e.tag or "") != tag:
                continue
            sub.events.append(e)
        return sub

    def axes(self) -> List[str]:
        return sorted({e.axis or "" for e in self.events})

    def tags(self) -> List[str]:
        return sorted({e.tag or "" for e in self.events})

    def by_axis(self) -> Dict[str, dict]:
        """Per-mesh-axis launch/byte totals — under a nested view this is
        the inter- vs. intra-axis communication split."""
        return {a: self.filter(axis=a).summary() for a in self.axes()}

    def by_tag(self) -> Dict[str, dict]:
        """Per-phase totals (RAMS: ``shuffle``, ``level0``, ``level1``, …).
        The tags partition the events, so the per-tag summaries sum back to
        :meth:`summary` — the per-level attribution invariant."""
        return {t: self.filter(tag=t).summary() for t in self.tags()}

    def summary(self, p: Optional[int] = None) -> dict:
        s = {
            "launches": self.launches,
            "p2p_launches": self.p2p_launches,
            "fused_launches": self.fused_launches,
            "counts": self.counts(),
            "bytes": self.payload_bytes(),
            "wire_bytes": self.wire_bytes(),
        }
        if p is not None:
            s["fused_hops"] = self.fused_hops(p)
        return s


# Phase tag recorded onto CommEvents (e.g. "shuffle", "level0").  A
# ContextVar for the same reason as the backend scope: tags are read at
# trace time and must be per-thread.
_TAG: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "repro_comm_tag", default=None)


@contextlib.contextmanager
def tagged(tag: Optional[str]):
    """Label every collective traced in this scope with an algorithm-phase
    tag (recorded by :class:`CountingCollectives`; a no-op otherwise).
    RAMS tags its initial shuffle and each level, which is what lets a
    counted trace attribute launches/bytes per level."""
    token = _TAG.set(tag)
    try:
        yield
    finally:
        _TAG.reset(token)


def current_tag() -> Optional[str]:
    return _TAG.get()


class CountingCollectives(Collectives):
    """Decorator backend: forward to ``inner``, record a :class:`CommTrace`.

    Wraps *any* backend (sim or shard_map), so the same counted trace is
    available whichever way the body executes.  Records the collective as
    issued at the call site — e.g. one grouped all_gather is one fused
    launch regardless of how :class:`SimCollectives` emulates it.  Each
    event carries the axis name the launch targeted and the active
    :func:`tagged` phase; under a :class:`NestedCollectives` view, place
    the counter *inside* the view (``NestedCollectives(inner=counter)``)
    to record the decomposed per-real-axis launches.
    """

    def __init__(self, inner: Collectives, trace: Optional[CommTrace] = None):
        self.inner = inner
        self.trace = trace if trace is not None else CommTrace()
        self.name = f"counting({inner.name})"

    @staticmethod
    def _gsize(axis_index_groups) -> Optional[int]:
        if axis_index_groups is None:
            return None
        return len(list(list(axis_index_groups)[0]))

    def axis_index(self, axis_name):
        return self.inner.axis_index(axis_name)       # not a communication

    def ppermute(self, x, axis_name, perm):
        self.trace.add("ppermute", _payload_bytes(x), axis=axis_name,
                       tag=_TAG.get())
        return self.inner.ppermute(x, axis_name, perm)

    def psum(self, x, axis_name, axis_index_groups=None):
        self.trace.add("psum", _payload_bytes(x),
                       self._gsize(axis_index_groups), axis=axis_name,
                       tag=_TAG.get())
        return self.inner.psum(x, axis_name,
                               axis_index_groups=axis_index_groups)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        self.trace.add("all_gather", _payload_bytes(x),
                       self._gsize(axis_index_groups), axis=axis_name,
                       tag=_TAG.get())
        return self.inner.all_gather(x, axis_name,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        self.trace.add("all_to_all", _payload_bytes(x),
                       self._gsize(axis_index_groups), axis=axis_name,
                       tag=_TAG.get())
        return self.inner.all_to_all(x, axis_name, split_axis=split_axis,
                                     concat_axis=concat_axis,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)

    def alltoall_stream(self, x, axis_name, fold, init, gsize,
                        axis_index_groups=None):
        # One event per delivered chunk, tagged ``ovl:<phase>`` — the gsize
        # chunk events sum exactly to the barrier path's single all_to_all
        # event for the same buffers (every leaf's shape[0] divides gsize).
        # Recorded here rather than inside the ring: the scan body traces
        # once, so counting the inner ppermutes would record one launch.
        per_chunk = _payload_bytes(x) // max(int(gsize), 1)
        tag = f"ovl:{_TAG.get() or ''}"
        for _ in range(int(gsize)):
            self.trace.add("all_to_all", per_chunk,
                           self._gsize(axis_index_groups), axis=axis_name,
                           tag=tag)
        return self.inner.alltoall_stream(x, axis_name, fold, init, gsize,
                                          axis_index_groups=axis_index_groups)


@contextlib.contextmanager
def counting(inner: Optional[Collectives] = None):
    """Scope a counting decorator over ``inner`` (default: current backend);
    yields the :class:`CommTrace` being filled.  Must wrap *tracing* — a
    jit cache hit records nothing.  A ``counting()`` scope survives entry
    into :func:`sim_map`: the runner re-wraps its sim backend with the
    same trace, so ``with comm.counting() as tr: psort(..., backend="sim")``
    records the simulated run's collectives."""
    cc = CountingCollectives(inner if inner is not None else current())
    with use(cc):
        yield cc.trace


# ---------------------------------------------------------------------------
# Fault injection: PEFailure + FaultPlan + FaultyCollectives
# ---------------------------------------------------------------------------


class PEFailure(RuntimeError):
    """A (simulated) PE died mid-collective.

    Raised **at trace time** by :class:`FaultyCollectives` when a planned
    kill fires, aborting the traced computation the way a dead participant
    aborts a fused collective for its whole group.  Carries the identity
    the rescale path needs (``repro.runtime.elastic.plan_sort_rescale``):
    the flat PE rank, the phase tag, and the primitive/axis of the launch
    that observed the failure.  The ``psort`` fault driver also raises it
    with ``phase="straggler"`` to route a watchdog-flagged PE down the
    same exclude-and-rescale path.
    """

    def __init__(self, pe: int, phase: Optional[str] = None,
                 primitive: Optional[str] = None, axis: Optional[str] = None):
        self.pe = int(pe)
        self.phase = phase
        self.primitive = primitive
        self.axis = axis
        super().__init__(
            f"PE {self.pe} failed during {primitive or 'collective'} "
            f"(axis={axis!r}, phase={phase!r})")


@dataclasses.dataclass(frozen=True)
class PEFault:
    """One planned fault: kill or delay PE ``pe``.

    ``tag`` names the phase (:func:`tagged`) whose collectives trigger the
    fault; ``None`` matches any phase, so the fault fires at the first
    collective of the run.  ``after`` skips that many matching launches
    first — the fault fires on the (``after`` + 1)-th.  ``factor`` is the
    simulated step-time stretch of a ``delay`` fault, the straggler signal
    ``repro.runtime.failures.flag_stragglers`` thresholds against
    ``k_mad`` deviations.

    PE indices are flat ranks in the topology of the attempt the fault
    fires in; after a rescale the driver drops plans whose ``pe`` fell off
    the shrunken mesh.
    """

    kind: str                       # "kill" | "delay"
    pe: int
    tag: Optional[str] = None       # phase tag to fire at; None = any
    after: int = 0                  # matching launches to let pass first
    factor: float = 4.0             # step-time stretch of a delay

    def __post_init__(self):
        if self.kind not in ("kill", "delay"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


def kill_pe(pe: int, tag: Optional[str] = None, after: int = 0) -> PEFault:
    """A fault that kills PE ``pe`` at phase ``tag``."""
    return PEFault("kill", int(pe), tag, int(after))


def delay_pe(pe: int, factor: float = 4.0, tag: Optional[str] = None,
             after: int = 0) -> PEFault:
    """A fault that stretches PE ``pe``'s simulated step time ×``factor``."""
    return PEFault("delay", int(pe), tag, int(after), float(factor))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic set of :class:`PEFault` to execute during one run."""

    faults: Tuple[PEFault, ...] = ()

    def __bool__(self) -> bool:
        return bool(self.faults)

    def surviving(self, pe: int, p_new: int) -> "FaultPlan":
        """The plan after PE ``pe`` was excluded and the topology shrank
        to ``p_new``: drop its faults and any targeting off-mesh ranks."""
        return FaultPlan(tuple(f for f in self.faults
                               if f.pe != pe and f.pe < p_new))


class FaultyCollectives(Collectives):
    """Decorator backend: forward to ``inner``, executing a ``FaultPlan``.

    Mirrors :class:`CountingCollectives` — wraps any backend and checks
    the plan on every collective launch at trace time.  A matching *kill*
    records a ``fault:kill`` event and raises :class:`PEFailure`; a
    matching *delay* records ``fault:delay`` and accumulates the stretch
    factor in :attr:`fired_delays` (read by the ``psort`` fault driver to
    synthesize per-PE step times for the watchdog lane).  Injected events
    go to ``trace`` — defaulting to the wrapped backend's trace when it is
    a :class:`CountingCollectives`, so one :class:`CommTrace` interleaves
    the injected events with the regular launches per axis/tag.

    Like :func:`counting`, the decorator acts while the body is *traced*:
    a jit cache hit replays neither launches nor faults, so the fault lane
    always executes under a fresh trace (``psort``'s driver jits each
    attempt anew).
    """

    def __init__(self, inner: Collectives, plan: FaultPlan,
                 trace: Optional[CommTrace] = None):
        self.inner = inner
        self.plan = plan if isinstance(plan, FaultPlan) \
            else FaultPlan(tuple(plan))
        if trace is None:
            trace = getattr(inner, "trace", None)
        self.trace = trace if trace is not None else CommTrace()
        self.fired_delays: Dict[int, float] = {}
        self._launches: Dict[PEFault, int] = {}
        self._done: Set[PEFault] = set()
        self.name = f"faulty({inner.name})"

    def _inject(self, primitive: str, axis_name) -> None:
        tag = _TAG.get()
        pending = [f for f in self.plan.faults if f not in self._done
                   and (f.tag is None or f.tag == tag)]
        # kills outrank delays within one launch: the PE dies before its
        # slowdown could be observed
        for f in sorted(pending, key=lambda f: f.kind != "kill"):
            seen = self._launches.get(f, 0) + 1
            self._launches[f] = seen
            if seen <= f.after:
                continue
            self._done.add(f)
            if f.kind == "kill":
                self.trace.add("fault:kill", 0, axis=str(axis_name),
                               tag=tag, pe=f.pe)
                raise PEFailure(f.pe, phase=tag, primitive=primitive,
                                axis=str(axis_name))
            self.trace.add("fault:delay", 0, axis=str(axis_name),
                           tag=tag, pe=f.pe)
            self.fired_delays[f.pe] = max(self.fired_delays.get(f.pe, 1.0),
                                          f.factor)

    def axis_index(self, axis_name):
        return self.inner.axis_index(axis_name)       # not a communication

    def ppermute(self, x, axis_name, perm):
        self._inject("ppermute", axis_name)
        return self.inner.ppermute(x, axis_name, perm)

    def psum(self, x, axis_name, axis_index_groups=None):
        self._inject("psum", axis_name)
        return self.inner.psum(x, axis_name,
                               axis_index_groups=axis_index_groups)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        self._inject("all_gather", axis_name)
        return self.inner.all_gather(x, axis_name,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        self._inject("all_to_all", axis_name)
        return self.inner.all_to_all(x, axis_name, split_axis=split_axis,
                                     concat_axis=concat_axis,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)

    def alltoall_stream(self, x, axis_name, fold, init, gsize,
                        axis_index_groups=None):
        # One logical collective, one injection point: a stream counts as a
        # single launch toward fault-plan ``after`` ordinals, same as the
        # barrier all_to_all it replaces.
        self._inject("all_to_all", axis_name)
        return self.inner.alltoall_stream(x, axis_name, fold, init, gsize,
                                          axis_index_groups=axis_index_groups)


@contextlib.contextmanager
def faulty(plan: FaultPlan, inner: Optional[Collectives] = None):
    """Scope a :class:`FaultyCollectives` over ``inner`` (default: the
    current backend); yields the decorator so the caller can read
    :attr:`FaultyCollectives.fired_delays` afterwards.  Must wrap
    *tracing*, exactly like :func:`counting` — and like a ``counting()``
    scope it survives entry into :func:`sim_map`, which re-wraps its sim
    backend with the same plan state."""
    fc = FaultyCollectives(inner if inner is not None else current(), plan)
    with use(fc):
        yield fc


# ---------------------------------------------------------------------------
# Simulation backend
# ---------------------------------------------------------------------------


def _group_tables(axis_index_groups):
    """Static lookup tables for grouped collectives.

    Returns (members, rank): ``members[i]`` lists the PEs of i's group in
    group order; ``rank[i]`` is i's position within its group.  Groups must
    partition the axis and share one size (the jax.lax contract).
    """
    groups = [list(g) for g in axis_index_groups]
    size = len(groups[0])
    assert all(len(g) == size for g in groups), "groups must be equal-sized"
    p = sum(len(g) for g in groups)
    assert sorted(pe for g in groups for pe in g) == list(range(p)), \
        "groups must partition the axis"
    members = np.zeros((p, size), np.int32)
    rank = np.zeros((p,), np.int32)
    for g in groups:
        for r, pe in enumerate(g):
            members[pe] = g
            rank[pe] = r
    return members, rank


def _is_full_identity_group(axis_index_groups) -> bool:
    groups = [list(g) for g in axis_index_groups]
    if len(groups) != 1:
        return False
    return groups[0] == list(range(len(groups[0])))


def _ring_perm(members: np.ndarray, rank: np.ndarray):
    """Static (source, dest) pairs: every PE receives from its next group
    neighbor (ring order within each group).  Applying it t times hands PE
    of rank r the value of group member (r + t) mod g."""
    p, g = members.shape
    return [(int(members[i][(rank[i] + 1) % g]), i) for i in range(p)]


# Above this batched-buffer size, grouped sim collectives switch from the
# one-shot full all_gather (fast, O(p²·payload) peak memory once vmap
# batches it) to the chunked ring evaluation (O(p·g·payload)).
SIM_CHUNK_BYTES = int(os.environ.get("REPRO_SIM_CHUNK_BYTES", 1 << 28))


class SimCollectives(Collectives):
    """Collectives valid under ``jax.vmap(..., axis_name=...)``.

    Ungrouped primitives delegate to ``jax.lax`` (vmap has batching rules
    for them with semantics identical to shard_map's).  Grouped variants,
    which vmap's collective batching rejects, are built from static group
    tables with three evaluation strategies per leaf:

      * degenerate groups (size 1, or one group in axis order) reduce to
        local ops / the native ungrouped collective;
      * small leaves: one full ``all_gather`` + table lookup (one-shot);
      * large leaves (batched gather > ``chunk_bytes``): a ``lax.scan``
        ring of ``ppermute`` steps — one PE block moves per iteration, so
        the p² buffer never materializes.  Integer results are bit-identical
        to the one-shot path; float grouped psum may differ in summation
        order (ring order instead of group order).
    """

    name = "sim"

    def __init__(self, chunk_bytes: Optional[int] = None):
        self.chunk_bytes = SIM_CHUNK_BYTES if chunk_bytes is None \
            else int(chunk_bytes)

    def _use_ring(self, v, p: int) -> bool:
        # the one-shot path batches an all_gather: (p, p, ...) elements
        return p * p * _payload_bytes(v) > max(0, self.chunk_bytes)

    def axis_index(self, axis_name):
        return jax.lax.axis_index(axis_name)

    def ppermute(self, x, axis_name, perm):
        return jax.lax.ppermute(x, axis_name, perm)

    # -- grouped helpers --------------------------------------------------

    @staticmethod
    def _my_rank(rank, axis_name):
        return jnp.take(jnp.asarray(rank), jax.lax.axis_index(axis_name))

    @staticmethod
    def _ring_parts(v, axis_name, perm, gsize):
        """scan the ring: parts[t] = my group member (rank+t)'s ``v``."""
        def step(carry, _):
            return jax.lax.ppermute(carry, axis_name, perm), carry
        _, parts = jax.lax.scan(step, v, None, length=gsize)
        return parts                                   # (gsize,) + v.shape

    def psum(self, x, axis_name, axis_index_groups=None):
        if axis_index_groups is None or \
                _is_full_identity_group(axis_index_groups):
            return jax.lax.psum(x, axis_name)
        members, rank = _group_tables(axis_index_groups)
        p, gsize = members.shape
        if gsize == 1:
            return x
        perm = _ring_perm(members, rank)

        def one(v):
            if self._use_ring(v, p):
                def step(carry, _):
                    rot, acc = carry
                    rot = jax.lax.ppermute(rot, axis_name, perm)
                    return (rot, acc + rot), None
                (_, acc), _ = jax.lax.scan(step, (v, v), None,
                                           length=gsize - 1)
                return acc
            g = jax.lax.all_gather(v, axis_name)          # (p, ...)
            mine = jnp.take(jnp.asarray(members),
                            jax.lax.axis_index(axis_name), axis=0)
            # dtype= matches lax.psum's dtype-preserving contract (a bare
            # sum promotes int32 → int64 under jax_enable_x64)
            return jnp.sum(jnp.take(g, mine, axis=0), axis=0, dtype=v.dtype)

        return jax.tree.map(one, x)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        if axis_index_groups is None or \
                _is_full_identity_group(axis_index_groups):
            return jax.lax.all_gather(x, axis_name, tiled=tiled)
        members, rank = _group_tables(axis_index_groups)
        p, gsize = members.shape
        if gsize == 1:
            def solo(v):
                return v if tiled else v[None]
            return jax.tree.map(solo, x)
        perm = _ring_perm(members, rank)

        def one(v):
            if self._use_ring(v, p):
                parts = self._ring_parts(v, axis_name, perm, gsize)
                r = self._my_rank(rank, axis_name)
                # group order: out[j] = member j's value = parts[(j-r) mod g]
                idx = (jnp.arange(gsize) - r) % gsize
                out = jnp.take(parts, idx, axis=0)        # (gsize, ...)
            else:
                g = jax.lax.all_gather(v, axis_name)      # (p, ...)
                mine = jnp.take(jnp.asarray(members),
                                jax.lax.axis_index(axis_name), axis=0)
                out = jnp.take(g, mine, axis=0)           # (gsize, ...)
            if tiled:
                out = out.reshape((-1,) + out.shape[2:])
            return out

        return jax.tree.map(one, x)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        if axis_index_groups is None or \
                _is_full_identity_group(axis_index_groups):
            return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                                      concat_axis=concat_axis, tiled=tiled)
        if split_axis != 0 or concat_axis != 0 or not tiled:
            raise NotImplementedError(
                "sim grouped all_to_all supports tiled split/concat axis 0")
        members, rank = _group_tables(axis_index_groups)
        p, gsize = members.shape
        if gsize == 1:
            return x
        perm = _ring_perm(members, rank)

        def one(v):
            assert v.shape[0] % gsize == 0, (v.shape, gsize)
            blk = v.shape[0] // gsize
            me = jax.lax.axis_index(axis_name)
            if self._use_ring(v, p):
                r = self._my_rank(rank, axis_name)

                def step(carry, _):
                    # carry = buffer of group member (rank + t); its block
                    # destined to me sits at my rank's offset
                    y = jax.lax.dynamic_slice_in_dim(carry, r * blk, blk,
                                                     axis=0)
                    return jax.lax.ppermute(carry, axis_name, perm), y

                _, ys = jax.lax.scan(step, v, None, length=gsize)
                idx = (jnp.arange(gsize) - r) % gsize     # → group order
                out = jnp.take(ys, idx, axis=0)           # (gsize, blk, ...)
                return out.reshape((-1,) + out.shape[2:])
            g = jax.lax.all_gather(v, axis_name)          # (p, gsize*blk, ...)
            mine = jnp.take(jnp.asarray(members), me, axis=0)
            r = jnp.take(jnp.asarray(rank), me)
            sel = jnp.take(g, mine, axis=0)               # (gsize, gsize*blk, ...)
            out = jax.lax.dynamic_slice_in_dim(sel, r * blk, blk, axis=1)
            return out.reshape((-1,) + out.shape[2:])     # (gsize*blk, ...)

        return jax.tree.map(one, x)

    def alltoall_stream(self, x, axis_name, fold, init, gsize,
                        axis_index_groups=None):
        # Always the chunked ring (the very scan the grouped all_to_all
        # uses for large leaves) — streaming is the point, so no one-shot
        # gather fallback regardless of payload size.
        return self._stream_ring(x, axis_name, fold, init, gsize,
                                 axis_index_groups=axis_index_groups)


# ---------------------------------------------------------------------------
# Nested-axis view: one virtual flat axis over an (outer, inner) axis pair
# ---------------------------------------------------------------------------


class NestedCollectives(Collectives):
    """View an ``(outer, inner)`` pair of named mesh axes as one flat axis.

    The sorting algorithms are written against a single named axis of size
    ``p`` (the PR-3 topology contract).  On a hierarchical mesh — e.g.
    inter-host × intra-host, the structure the multi-level scheme of
    arXiv 1410.6754 maps AMS levels onto — the ``p`` participants are laid
    out over *two* named axes ``axes = ((outer, p_o), (inner, p_i))`` with
    flat index ``outer·p_i + inner``.  This view accepts the algorithms'
    collectives on the **virtual** flat axis and decomposes each into
    collectives over the real axes of the wrapped backend:

      * calls naming a real axis pass through unchanged;
      * ``axis_index(virtual)`` composes the per-axis indices;
      * ``ppermute`` permutations must factor through one axis (XOR
        hypercube perms always do: bit ``j`` permutes the inner axis when
        ``j < log2 p_i``, else the outer axis);
      * grouped collectives classify their ``axis_index_groups``: groups
        lying inside one inner slice (with the same pattern in every
        slice, e.g. subcubes of size ≤ p_i) retarget onto the inner axis
        only; groups that are unions of whole outer slices (subcubes of
        size ≥ p_i) decompose into an inner-axis stage plus an outer-axis
        stage.  A full-axis ``all_to_all`` becomes one all_to_all over the
        slow outer axis and one over the inner axis.

    Every decomposition is **element-exact** (same values in the same
    places, not just the same multiset), which is what makes nested runs
    bitwise-identical to the flat ``axis_index_groups`` path.  The wrapped
    backend may be :data:`LAX` (shard_map over a real multi-axis mesh),
    :data:`SIM` (nested vmaps, see :func:`sim_map`'s ``nested=`` mode), or
    a :class:`CountingCollectives` over either — in which case the trace
    records the decomposed launches with their real axis names, splitting
    inter- from intra-axis volume.
    """

    def __init__(self, inner: Collectives, virtual_axis: str,
                 axes: Sequence):
        axes = tuple((str(n), int(s)) for n, s in axes)
        if len(axes) != 2:
            raise NotImplementedError(
                f"NestedCollectives supports exactly 2 nested axes; "
                f"got {axes}")
        self.inner = inner
        self.virtual_axis = virtual_axis
        self.axes = axes
        (self._oa, self._po), (self._ia, self._pi) = axes
        self.p = self._po * self._pi
        self.name = f"nested({inner.name})"

    # -- classification helpers ------------------------------------------

    def _factor_perm(self, perm):
        """Express a flat-axis permutation as a single real-axis ppermute."""
        po, pi = self._po, self._pi
        pairs = [(int(s), int(d)) for s, d in perm]
        srcs = sorted(s for s, _ in pairs)
        dsts = sorted(d for _, d in pairs)
        if srcs == dsts == list(range(self.p)):
            if all(s // pi == d // pi for s, d in pairs):
                maps = [{} for _ in range(po)]
                for s, d in pairs:
                    maps[s // pi][s % pi] = d % pi
                if all(m == maps[0] for m in maps):
                    return self._ia, sorted(maps[0].items())
            if all(s % pi == d % pi for s, d in pairs):
                maps = [{} for _ in range(pi)]
                for s, d in pairs:
                    maps[s % pi][s // pi] = d // pi
                if all(m == maps[0] for m in maps):
                    return self._oa, sorted(maps[0].items())
        raise NotImplementedError(
            f"virtual-axis ppermute does not factor through one of the "
            f"nested axes {self.axes}: {perm}")

    def _classify_groups(self, axis_index_groups):
        """(mode, groups) with mode 'inner' (retarget onto the inner axis)
        or 'outer' (decompose: full inner stage + grouped outer stage).
        ``groups`` are along the real axis; ``None`` = the full axis."""
        po, pi = self._po, self._pi
        if axis_index_groups is None:
            return "outer", None
        groups = [list(map(int, g)) for g in axis_index_groups]
        if _is_full_identity_group(groups) and len(groups[0]) == self.p:
            return "outer", None
        gsize = len(groups[0])
        # groups inside one inner slice, same pattern in every slice
        if gsize <= pi and all(pe // pi == g[0] // pi
                               for g in groups for pe in g):
            per_slice = [[] for _ in range(po)]
            for g in groups:
                per_slice[g[0] // pi].append(tuple(pe % pi for pe in g))
            pattern = sorted(per_slice[0])
            if all(sorted(s) == pattern for s in per_slice):
                inner_groups = [list(g) for g in pattern]
                if _is_full_identity_group(inner_groups) and \
                        len(inner_groups[0]) == pi:
                    return "inner", None
                return "inner", inner_groups
        # groups that are unions of whole outer slices, flat-ascending
        if gsize % pi == 0:
            outer_groups = []
            for g in groups:
                outs = sorted({pe // pi for pe in g})
                if g != [o * pi + i for o in outs for i in range(pi)]:
                    break
                outer_groups.append(outs)
            else:
                if len(outer_groups) == 1 and \
                        outer_groups[0] == list(range(po)):
                    return "outer", None
                return "outer", outer_groups
        raise NotImplementedError(
            f"axis_index_groups do not align with the nested axes "
            f"{self.axes}: {axis_index_groups}")

    # -- the Collectives interface ---------------------------------------

    def axis_index(self, axis_name):
        if axis_name != self.virtual_axis:
            return self.inner.axis_index(axis_name)
        o = self.inner.axis_index(self._oa)
        i = self.inner.axis_index(self._ia)
        return (o * self._pi + i).astype(jnp.int32)

    def ppermute(self, x, axis_name, perm):
        if axis_name != self.virtual_axis:
            return self.inner.ppermute(x, axis_name, perm)
        ax, real_perm = self._factor_perm(perm)
        return self.inner.ppermute(x, ax, real_perm)

    def psum(self, x, axis_name, axis_index_groups=None):
        if axis_name != self.virtual_axis:
            return self.inner.psum(x, axis_name,
                                   axis_index_groups=axis_index_groups)
        mode, g = self._classify_groups(axis_index_groups)
        if mode == "inner":
            return self.inner.psum(x, self._ia, axis_index_groups=g)
        s = self.inner.psum(x, self._ia)
        return self.inner.psum(s, self._oa, axis_index_groups=g)

    def all_gather(self, x, axis_name, axis_index_groups=None, tiled=False):
        if axis_name != self.virtual_axis:
            return self.inner.all_gather(x, axis_name,
                                         axis_index_groups=axis_index_groups,
                                         tiled=tiled)
        mode, g = self._classify_groups(axis_index_groups)
        if mode == "inner":
            return self.inner.all_gather(x, self._ia, axis_index_groups=g,
                                         tiled=tiled)
        gi = self.inner.all_gather(x, self._ia)              # (p_i,) + shape
        go = self.inner.all_gather(gi, self._oa,
                                   axis_index_groups=g)  # (g_o, p_i) + shape

        def flatten(v):
            v = v.reshape((-1,) + v.shape[2:])               # group order
            if tiled:
                v = v.reshape((-1,) + v.shape[2:])
            return v

        return jax.tree.map(flatten, go)

    def all_to_all(self, x, axis_name, split_axis=0, concat_axis=0,
                   axis_index_groups=None, tiled=False):
        if axis_name != self.virtual_axis:
            return self.inner.all_to_all(x, axis_name, split_axis=split_axis,
                                         concat_axis=concat_axis,
                                         axis_index_groups=axis_index_groups,
                                         tiled=tiled)
        mode, g = self._classify_groups(axis_index_groups)
        if mode == "inner":
            return self.inner.all_to_all(x, self._ia, split_axis=split_axis,
                                         concat_axis=concat_axis,
                                         axis_index_groups=g, tiled=tiled)
        if split_axis != 0 or concat_axis != 0 or not tiled:
            raise NotImplementedError(
                "nested virtual all_to_all supports tiled split/concat axis 0")
        pi = self._pi
        g_out = self._po if g is None else len(g[0])
        gsize = g_out * pi

        def one(v):
            assert v.shape[0] % gsize == 0, (v.shape, gsize)
            blk = v.shape[0] // gsize
            # stage 1 — slow axis: chunk jo of the input (p_i·blk rows) is
            # the blocks destined to outer slice jo; after the exchange,
            # y[jo] holds member (jo, my_inner)'s blocks for my slice.
            y = self.inner.all_to_all(v, self._oa, split_axis=0,
                                      concat_axis=0, axis_index_groups=g,
                                      tiled=True)
            y3 = y.reshape((g_out, pi, blk) + v.shape[1:])
            # stage 2 — inner axis: deliver within the slice.  Transposed
            # so the inner a2a splits on axis 0 (both backends support it).
            yt = jnp.moveaxis(y3, 1, 0).reshape((pi * g_out * blk,)
                                                + v.shape[1:])
            z = self.inner.all_to_all(yt, self._ia, split_axis=0,
                                      concat_axis=0, tiled=True)
            z3 = z.reshape((pi, g_out, blk) + v.shape[1:])
            return jnp.moveaxis(z3, 1, 0).reshape((gsize * blk,)
                                                  + v.shape[1:])

        return jax.tree.map(one, x)


@contextlib.contextmanager
def nested(virtual_axis: str, axes, inner: Optional[Collectives] = None):
    """Scope a :class:`NestedCollectives` view over ``inner`` (default: the
    current backend) — the shard_map-side entry point: wrap the *tracing*
    of a body whose collectives name ``virtual_axis`` while the mesh
    carries the real ``axes``.  A surrounding :func:`counting` scope keeps
    counting, now with per-real-axis attribution."""
    base = inner if inner is not None else current()
    with use(NestedCollectives(base, virtual_axis, axes)):
        yield


LAX = LaxCollectives()
SIM = SimCollectives()

# ContextVar, not a module global: tracing may happen from several threads
# (e.g. two jit cache misses racing), and each trace must see its own
# backend scope.
_CURRENT: contextvars.ContextVar[Collectives] = contextvars.ContextVar(
    "repro_collectives", default=LAX)


def current() -> Collectives:
    return _CURRENT.get()


@contextlib.contextmanager
def use(impl: Collectives):
    """Scope the active collectives backend (around *tracing*)."""
    token = _CURRENT.set(impl)
    try:
        yield impl
    finally:
        _CURRENT.reset(token)


# --- module-level dispatchers: the call-site API ---------------------------


def axis_index(axis_name):
    return _CURRENT.get().axis_index(axis_name)


def ppermute(x, axis_name, perm):
    return _CURRENT.get().ppermute(x, axis_name, perm)


def psum(x, axis_name, axis_index_groups=None):
    return _CURRENT.get().psum(x, axis_name,
                               axis_index_groups=axis_index_groups)


def all_gather(x, axis_name, axis_index_groups=None, tiled=False):
    return _CURRENT.get().all_gather(x, axis_name,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)


def all_to_all(x, axis_name, split_axis=0, concat_axis=0,
               axis_index_groups=None, tiled=False):
    return _CURRENT.get().all_to_all(x, axis_name, split_axis=split_axis,
                                     concat_axis=concat_axis,
                                     axis_index_groups=axis_index_groups,
                                     tiled=tiled)


def alltoall_stream(x, axis_name, fold, init, gsize, axis_index_groups=None):
    return _CURRENT.get().alltoall_stream(
        x, axis_name, fold, init, gsize,
        axis_index_groups=axis_index_groups)


# --- simulation runner -----------------------------------------------------


def sim_map(body, axis_name: str, p: Optional[int] = None,
            impl: Optional[Collectives] = None,
            mesh: Optional[Sequence[int]] = None,
            data_axis: Optional[str] = None,
            nested: Optional[Sequence] = None):
    """Run a per-PE SPMD ``body`` over a leading PE axis in one process.

    ``body`` is the same function one would pass to ``shard_map`` minus the
    leading block dimension: inputs/outputs are per-PE values, batched over
    axis 0 of the arguments.  Collectives inside the body must go through
    this module; they dispatch to ``impl`` while the body is traced — pass
    a :class:`CountingCollectives` wrapping :data:`SIM` to record the
    collective trace of a simulated run, or a
    ``SimCollectives(chunk_bytes=...)`` to tune the chunking threshold.

    When ``impl`` is omitted the runner derives a sim-capable backend from
    the *ambient* scope at call time: a surrounding :func:`counting` scope
    keeps counting (re-wrapped over :data:`SIM` with the same trace), an
    ambient ``SimCollectives`` is kept as-is, and anything else (the
    shard_map default) becomes :data:`SIM`.

    **Multi-axis mode** — ``mesh=(d, p)`` emulates a 2-D device mesh
    ``(data_axis, axis_name)``: arguments carry two leading axes ``(d, p,
    ...)`` and the body runs once per (data, sort) coordinate.  Collectives
    inside the body name ``axis_name`` only, so they resolve within each
    row's p-sized sort subgroup — the ``d`` rows never communicate, exactly
    like ``shard_map`` over the sort axis of a 2-D mesh.  Implementation:
    the sort axis is the inner ``vmap(axis_name=...)`` (which gives the
    collectives their named axis) and the data axis an outer ``vmap``
    (named ``data_axis`` if given); vmap's collective batching rules carry
    the sort-axis collectives over the data axis unchanged, so each row is
    bit-identical to a standalone ``sim_map(body, axis_name, p)`` run.

    Sort each row of a batch within its own sort-axis subgroup:

    >>> import jax, jax.numpy as jnp
    >>> from repro.core import comm
    >>> d, p = 2, 4
    >>> def body(v):                       # v: this PE's () block
    ...     lo = comm.all_gather(v, "sort")       # (p,): my subgroup only
    ...     return jnp.sort(lo)[comm.axis_index("sort")]
    >>> x = jnp.array([[3, 1, 0, 2],
    ...                [7, 5, 6, 4]], jnp.int32)
    >>> run = comm.sim_map(body, "sort", p, mesh=(d, p), data_axis="data")
    >>> run(x)
    Array([[0, 1, 2, 3],
           [4, 5, 6, 7]], dtype=int32)

    **Nested-axis mode** — ``nested=(("inter", p_o), ("intra", p_i))``
    emulates a hierarchical mesh: arguments carry one leading axis per
    nested axis (outer first), the body runs once per (outer, inner)
    coordinate under nested ``vmap(axis_name=...)`` transforms, and the
    body's collectives on the *virtual* flat ``axis_name`` are decomposed
    onto the real axes by a :class:`NestedCollectives` view (``impl``, when
    given, becomes the view's wrapped backend).  Bit-identical to the flat
    ``sim_map(body, axis_name, p_o·p_i)`` run of the same body:

    >>> def body2(v):                      # v: this PE's () block
    ...     lo = comm.all_gather(v, "sort", tiled=True)   # all p_o*p_i
    ...     return jnp.sort(lo)[comm.axis_index("sort")]
    >>> y = jnp.array([[3, 1], [0, 2]], jnp.int32)        # (p_o, p_i)
    >>> comm.sim_map(body2, "sort", nested=(("inter", 2), ("intra", 2)))(y)
    Array([[0, 1],
           [2, 3]], dtype=int32)
    """

    def _resolve(cur: Collectives) -> Collectives:
        if isinstance(cur, SimCollectives):
            return cur
        if isinstance(cur, CountingCollectives):
            return CountingCollectives(_resolve(cur.inner), cur.trace)
        if isinstance(cur, FaultyCollectives):
            fc = FaultyCollectives(_resolve(cur.inner), cur.plan, cur.trace)
            # share mutable fault state so the ambient decorator observes
            # what fired inside the sim run
            fc.fired_delays = cur.fired_delays
            fc._launches = cur._launches
            fc._done = cur._done
            return fc
        return SIM

    if nested is not None:
        nested = tuple((str(n), int(s)) for n, s in nested)
        p_nested = 1
        for _, s in nested:
            p_nested *= s
        if p is not None and p != p_nested:
            raise ValueError(f"p={p} inconsistent with nested={nested}")
        p = p_nested

    if mesh is not None:
        d_sz, p_sz = (int(v) for v in mesh)
        if p is not None and p != p_sz:
            raise ValueError(f"p={p} inconsistent with mesh={tuple(mesh)}")
        p = p_sz
    else:
        d_sz = None

    def run(*args):
        axis_lead = tuple(s for _, s in nested) if nested is not None \
            else (p,)
        lead = ((d_sz,) + axis_lead) if d_sz is not None else axis_lead
        if p is not None:
            for a in jax.tree.leaves(args):
                assert a.shape[:len(lead)] == lead, (a.shape, lead)
        backend = impl if impl is not None else _resolve(current())
        if nested is not None:
            backend = NestedCollectives(backend, axis_name, nested)
        with use(backend):
            if nested is not None:
                f = body
                for name, _ in reversed(nested):
                    f = jax.vmap(f, axis_name=name)
            else:
                f = jax.vmap(body, axis_name=axis_name)
            if d_sz is not None:
                f = jax.vmap(f, axis_name=data_axis) if data_axis \
                    else jax.vmap(f)
            return f(*args)

    return run
