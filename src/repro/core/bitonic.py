"""Distributed bitonic sort (Batcher / Johnsson, paper §IV-D2) — the
deterministic baseline.  log²p compare-split rounds; every round exchanges
the *full* local block, which is why the β·(n/p)·log²p term makes it
unattractive outside a narrow band of input sizes (paper Table I).

Compare-split formulation with always-ascending local blocks: merge my
block with the partner's and keep the lower or upper half depending on the
stage direction.  Unlike the paper's implementation (which "fails to sort
sparse inputs"), the padded-buffer merge handles sparse and duplicate
inputs for free — padding is just the key-space maximum.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import comm
from .hypercube import exchange_shard
from .types import SortShard, local_sort, merge_shards, pad_value


class BitonicResult(NamedTuple):
    shard: SortShard
    overflow: jax.Array


def _split_half(merged: SortShard, cap: int, keep_low):
    """Take [0,cap) or [cap,2cap) of a sorted padded 2·cap shard."""
    pad = merged.pad
    idx = jnp.arange(cap, dtype=jnp.int32)
    lo_keys = merged.keys[:cap]
    hi_keys = merged.keys[cap:]
    lo_count = jnp.minimum(merged.count, cap)
    hi_count = jnp.maximum(merged.count - cap, 0)
    keys = jnp.where(keep_low, lo_keys, hi_keys)
    count = jnp.where(keep_low, lo_count, hi_count)
    vals = {k: jnp.where(keep_low, v[:cap], v[cap:])
            for k, v in merged.vals.items()}
    keys = jnp.where(idx < count, keys, pad)
    return SortShard(keys=keys, vals=vals, count=count.astype(jnp.int32))


def bitonic(shard: SortShard, axis_name: str, p: int) -> BitonicResult:
    d = p.bit_length() - 1
    cap = shard.capacity
    me = comm.axis_index(axis_name)
    shard = local_sort(shard)
    for k in range(d):                     # stage: sorted blocks of 2^(k+1)
        for j in range(k, -1, -1):         # substage distance 2^j
            partner = me ^ (1 << j)
            ascending = ((me >> (k + 1)) & 1) == 0
            keep_low = jnp.where(ascending, me < partner, me > partner)
            other = exchange_shard(shard, axis_name, p, j)
            # pair-consistent tie order (lower PE's elements first) so both
            # partners build the same merged sequence and split it disjointly
            merged, _ = merge_shards(shard, other, capacity=2 * cap,
                                     tie_a_first=(me < partner))
            shard = _split_half(merged, cap, keep_low)
    return BitonicResult(shard, jnp.int32(0))
