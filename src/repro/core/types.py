"""Core element representation for the distributed sorting library.

The paper's algorithms exchange *dynamically sized* MPI messages.  JAX is a
static-shape SPMD system, so every per-PE fragment of the input is held in a
fixed-capacity, ascending-sorted buffer padded with the key-space maximum:

    SortShard(keys=(C,), vals={name: (C,)}, count=())

``count`` is the number of valid elements; ``keys[count:] == PAD``.  The
capacity C is provisioned from the paper's own load guarantees (Lemma 3:
subcube imbalance is O(1) w.h.p. after the initial random shuffle) and every
algorithm returns an ``overflow`` flag that the tests assert to be zero on
all ten adversarial input distributions.

Keys are order-preserving bit-casts of the user dtype into uint32/uint64
(the classic monotone float transform), so all comparisons inside the
library are unsigned-integer comparisons and "+inf padding" is just the
all-ones word.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Order-preserving key transforms
# ---------------------------------------------------------------------------

_UINT_MAX = {jnp.dtype("uint32"): np.uint32(0xFFFFFFFF),
             jnp.dtype("uint64"): np.uint64(0xFFFFFFFFFFFFFFFF)}


def key_to_uint(x: jax.Array) -> jax.Array:
    """Map f32/f64/i32/i64/u32/u64 keys to unsigned ints, order-preserving."""
    dt = x.dtype
    if dt in (jnp.uint32, jnp.uint64):
        return x
    if dt == jnp.int32:
        return (x.view(jnp.uint32) ^ np.uint32(0x80000000)).astype(jnp.uint32)
    if dt == jnp.int64:
        return x.view(jnp.uint64) ^ np.uint64(0x8000000000000000)
    if dt == jnp.float32:
        b = x.view(jnp.uint32)
        # negative floats: flip all bits;  non-negative: flip the sign bit.
        mask = jnp.where(b >> 31 == 1, np.uint32(0xFFFFFFFF), np.uint32(0x80000000))
        return b ^ mask
    if dt == jnp.float64:
        b = x.view(jnp.uint64)
        mask = jnp.where(b >> 63 == 1, np.uint64(0xFFFFFFFFFFFFFFFF),
                         np.uint64(0x8000000000000000))
        return b ^ mask
    raise TypeError(f"unsupported key dtype {dt}")


def uint_to_key(u: jax.Array, orig_dtype) -> jax.Array:
    """Inverse of :func:`key_to_uint`."""
    dt = jnp.dtype(orig_dtype)
    if dt in (jnp.uint32, jnp.uint64):
        return u
    if dt == jnp.int32:
        return (u ^ np.uint32(0x80000000)).view(jnp.int32)
    if dt == jnp.int64:
        return (u ^ np.uint64(0x8000000000000000)).view(jnp.int64)
    if dt == jnp.float32:
        mask = jnp.where(u >> 31 == 1, np.uint32(0x80000000), np.uint32(0xFFFFFFFF))
        return (u ^ mask).view(jnp.float32)
    if dt == jnp.float64:
        mask = jnp.where(u >> 63 == 1, np.uint64(0x8000000000000000),
                         np.uint64(0xFFFFFFFFFFFFFFFF))
        return (u ^ mask).view(jnp.float64)
    raise TypeError(f"unsupported key dtype {dt}")


def pad_value(dtype) -> np.generic:
    return _UINT_MAX[jnp.dtype(dtype)]


# ---------------------------------------------------------------------------
# SortShard
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SortShard:
    """One PE's fixed-capacity fragment.  ``keys`` sorted ascending, padded."""

    keys: jax.Array                      # (C,) uint32/uint64
    vals: Dict[str, jax.Array]           # each (C,) — payload travels along
    count: jax.Array                     # () int32, number of valid entries

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def pad(self):
        return pad_value(self.keys.dtype)

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.count

    def replace(self, **kw) -> "SortShard":
        return dataclasses.replace(self, **kw)


def make_shard(keys: jax.Array, count=None, capacity: Optional[int] = None,
               vals: Optional[Dict[str, jax.Array]] = None,
               sort_local: bool = True) -> SortShard:
    """Build a SortShard from raw keys (any supported dtype)."""
    u = key_to_uint(keys)
    n = u.shape[0]
    cap = capacity or n
    if count is None:
        count = jnp.int32(n)
    count = jnp.asarray(count, jnp.int32)
    pad = pad_value(u.dtype)
    idx = jnp.arange(cap, dtype=jnp.int32)
    if cap != n:
        u = jnp.concatenate([u, jnp.full((cap - n,), pad, u.dtype)])
        vals = {k: jnp.concatenate(
                    [v, jnp.zeros((cap - n,) + v.shape[1:], v.dtype)])
                for k, v in (vals or {}).items()}
    vals = dict(vals or {})
    u = jnp.where(idx < count, u, pad)
    shard = SortShard(keys=u, vals=vals, count=count)
    if sort_local:
        shard = local_sort(shard)
    return shard


# Local-phase kernel policy.  Two Pallas kernels cover the local hot spots:
# the bitonic local sort (kernels/bitonic) and the fused partition-into-
# buckets classifier (kernels/partition).  On a TPU backend both default ON
# — the local phase is the speed floor of every algorithm here; everywhere
# else (CPU/sim CI) they default OFF because interpret-mode execution is
# slow, and the jnp paths are the bitwise oracle the kernels are diffed
# against.  The ``REPRO_LOCAL_KERNELS`` environment variable (read at trace
# time, so ``monkeypatch.setenv`` works) overrides the default:
#
#   REPRO_LOCAL_KERNELS=all | 1 | on      both kernels
#   REPRO_LOCAL_KERNELS=none | 0 | off    neither
#   REPRO_LOCAL_KERNELS=sort,partition    an explicit subset
#   REPRO_LOCAL_KERNELS=auto              backend default (TPU → both)
#
# The legacy sort-only toggles (``REPRO_PALLAS_LOCAL_SORT`` and
# :func:`set_pallas_local_sort`) still work and override the ``sort``
# component; :func:`set_local_kernels` overrides the whole policy.
_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("", "0", "none", "off", "false", "no")


@dataclasses.dataclass(frozen=True)
class LocalKernelPolicy:
    """Which Pallas local-phase kernels are active.  Frozen/hashable so it
    can key a jit cache (``psort`` passes it as a static argument)."""

    sort: bool = False
    partition: bool = False


_PALLAS_LOCAL_SORT_OVERRIDE: Optional[bool] = None
_LOCAL_KERNELS_OVERRIDE: Optional[LocalKernelPolicy] = None


def _default_local_kernels() -> LocalKernelPolicy:
    on = jax.default_backend() == "tpu"
    return LocalKernelPolicy(sort=on, partition=on)


def _parse_local_kernels(spec: str) -> LocalKernelPolicy:
    s = spec.strip().lower()
    if s == "auto":
        return _default_local_kernels()
    if s in _FALSY:
        return LocalKernelPolicy()
    if s == "all" or s in _TRUTHY:
        return LocalKernelPolicy(sort=True, partition=True)
    parts = {t.strip() for t in s.split(",") if t.strip()}
    unknown = parts - {"sort", "partition"}
    if unknown:
        raise ValueError(f"REPRO_LOCAL_KERNELS: unknown kernel(s) "
                         f"{sorted(unknown)} in {spec!r} (know: sort, "
                         f"partition, all, none, auto)")
    return LocalKernelPolicy(sort="sort" in parts,
                             partition="partition" in parts)


def local_kernels() -> LocalKernelPolicy:
    """The active local-kernel policy: programmatic override
    (:func:`set_local_kernels`) > ``REPRO_LOCAL_KERNELS`` > backend default
    (TPU → both on), with the legacy sort-only toggles layered on the
    ``sort`` component."""
    if _LOCAL_KERNELS_OVERRIDE is not None:
        return _LOCAL_KERNELS_OVERRIDE
    env = os.environ.get("REPRO_LOCAL_KERNELS")
    pol = _parse_local_kernels(env) if env is not None \
        else _default_local_kernels()
    if _PALLAS_LOCAL_SORT_OVERRIDE is not None:
        pol = dataclasses.replace(pol, sort=_PALLAS_LOCAL_SORT_OVERRIDE)
    else:
        legacy = os.environ.get("REPRO_PALLAS_LOCAL_SORT")
        if legacy is not None:
            pol = dataclasses.replace(pol, sort=legacy.lower() in _TRUTHY)
    return pol


def set_local_kernels(policy: Optional[LocalKernelPolicy]
                      ) -> Optional[LocalKernelPolicy]:
    """Force the whole kernel policy (``None`` = defer to the environment /
    backend default again).  Returns the previous override."""
    global _LOCAL_KERNELS_OVERRIDE
    prev = _LOCAL_KERNELS_OVERRIDE
    _LOCAL_KERNELS_OVERRIDE = policy
    return prev


def use_pallas_local_sort() -> bool:
    """Is the Pallas local-sort kernel enabled?  (Back-compat shim for the
    pre-policy spelling: equals ``local_kernels().sort``.)"""
    return local_kernels().sort


def set_pallas_local_sort(enabled: Optional[bool]) -> Optional[bool]:
    """Force the Pallas local-sort path on/off (``None`` = defer to the
    environment variable again).  Returns the previous override so callers
    can restore it."""
    global _PALLAS_LOCAL_SORT_OVERRIDE
    prev = _PALLAS_LOCAL_SORT_OVERRIDE
    _PALLAS_LOCAL_SORT_OVERRIDE = enabled
    return prev


def local_sort(shard: SortShard) -> SortShard:
    """Sort a shard's valid elements ascending (stable w.r.t. input order)."""
    pad = shard.pad
    keys = jnp.where(shard.valid_mask(), shard.keys, pad)
    if use_pallas_local_sort() and _pallas_sortable(shard):
        from repro.kernels.bitonic import local_sort_fast
        if not shard.vals:
            return shard.replace(keys=local_sort_fast(keys))
        (vname, vals), = shard.vals.items()
        ks, vs = local_sort_fast(keys, vals)
        return shard.replace(keys=ks, vals={vname: vs})
    if not shard.vals:
        return shard.replace(keys=jnp.sort(keys))
    order = jnp.argsort(keys, stable=True)
    return shard.replace(keys=keys[order],
                         vals={k: v[order] for k, v in shard.vals.items()})


def _pallas_sortable(shard: SortShard) -> bool:
    from repro.kernels.bitonic import supported
    if not supported(shard.capacity, shard.keys.dtype):
        return False
    if len(shard.vals) > 1:
        return False
    return all(jnp.dtype(v.dtype).itemsize == 4 and v.ndim == 1
               for v in shard.vals.values())


# ---------------------------------------------------------------------------
# Padded merge of two ascending-sorted shards
# ---------------------------------------------------------------------------


def _take(shard_keys, vals, order):
    return shard_keys[order], {k: v[order] for k, v in vals.items()}


def merge_shards(a: SortShard, b: SortShard, capacity: Optional[int] = None,
                 tie_a_first: bool = True):
    """Merge two sorted padded shards into one of size ``capacity``.

    Returns (merged, overflow) where overflow counts elements dropped because
    the combined valid count exceeded the capacity.  On ties, elements of
    ``a`` precede elements of ``b`` (the stable "left block first" rule that
    realizes the paper's implicit origin-ordering, cf. RFIS tie-breaking).
    """
    cap = capacity or max(a.capacity, b.capacity)
    total = a.count + b.count
    keys = jnp.concatenate([a.keys, b.keys])
    # Padding must sort *after* any real element of the same (max) key value:
    # give each entry a secondary "is-padding" flag and lexsort.  For the
    # common key-only case a plain sort is sufficient and cheaper only when
    # no payload exists AND keys cannot collide with the pad word; we keep
    # the safe path everywhere (XLA fuses the two sort passes anyway).
    # ``tie_a_first`` may be a traced bool (e.g. bitonic's compare-split
    # needs the *pair-consistent* lower-PE-first order so both partners
    # construct the identical merged sequence).
    apad = ~a.valid_mask()
    bpad = ~b.valid_mask()
    tie_a = jnp.asarray(tie_a_first)
    # tie order: valid a (0) < valid b (1) < padding (2), flipped when !tie_a
    rank_a = jnp.where(apad, jnp.int32(2),
                       jnp.where(tie_a, jnp.int32(0), jnp.int32(1)))
    rank_b = jnp.where(bpad, jnp.int32(2),
                       jnp.where(tie_a, jnp.int32(1), jnp.int32(0)))
    rank_b = jnp.broadcast_to(rank_b, bpad.shape)
    rank_a = jnp.broadcast_to(rank_a, apad.shape)
    tie = jnp.concatenate([rank_a, rank_b])
    order = jnp.lexsort((tie, keys))
    vals = {k: jnp.concatenate([a.vals[k], b.vals[k]]) for k in a.vals}
    mk, mv = _take(keys, vals, order)
    if mk.shape[0] > cap:
        mk = mk[:cap]
        mv = {k: v[:cap] for k, v in mv.items()}
    elif mk.shape[0] < cap:
        pad = pad_value(mk.dtype)
        extra = cap - mk.shape[0]
        mk = jnp.concatenate([mk, jnp.full((extra,), pad, mk.dtype)])
        mv = {k: jnp.concatenate([v, jnp.zeros((extra,) + v.shape[1:], v.dtype)])
              for k, v in mv.items()}
    new_count = jnp.minimum(total, jnp.int32(cap))
    overflow = jnp.maximum(total - jnp.int32(cap), 0)
    # re-pad keys beyond count (dropped elements / stale pads)
    idx = jnp.arange(cap, dtype=jnp.int32)
    mk = jnp.where(idx < new_count, mk, pad_value(mk.dtype))
    return SortShard(keys=mk, vals=mv, count=new_count), overflow


def merge_sorted_shards(a: SortShard, b: SortShard,
                        capacity: Optional[int] = None):
    """Positional merge of two ascending-sorted shards (a-before-b ties).

    Produces the same ``(merged, overflow)`` as
    ``merge_shards(a, b, capacity, tie_a_first=True)`` on everything a
    consumer can observe — keys (the pad region is re-padded), counts,
    overflow, and vals in ``[0, count)`` — but computes each element's
    merged position directly with two ``searchsorted`` passes and scatters,
    instead of lexsorting the concatenation.  That turns the running-merge
    fold of a streamed exchange from O(C log C) per chunk into O(C), which
    is what makes the incremental consumer competitive with the barrier
    path's single post-shuffle sort.

    Vals beyond ``count`` are zeros on the scatter path and leftover pad
    payloads on the sort paths (the lexsort path leaves whatever the
    dropped pad entries carried); no caller reads them.
    """
    cap = capacity or max(a.capacity, b.capacity)
    ca, cb = a.count, b.count
    ma, mb = a.capacity, b.capacity
    total = ca + cb
    new_count = jnp.minimum(total, jnp.int32(cap))
    overflow = jnp.maximum(total - jnp.int32(cap), 0)
    idx = jnp.arange(cap, dtype=jnp.int32)

    # A per-element *rank* that realizes the merge's tie order when compared
    # after the key: valid a (own position) < valid b (ma + position) < pads
    # (ma + mb + concatenation position).  Ranks are unique across the
    # concatenation, so (key, rank) pairs are distinct and any (key, rank)
    # sort — stable or not — reproduces the lexsort permutation exactly.
    ia = jnp.arange(ma, dtype=jnp.int32)
    ib = jnp.arange(mb, dtype=jnp.int32)
    ra = jnp.where(ia < ca, ia, jnp.int32(ma + mb) + ia)
    rb = jnp.where(ib < cb, jnp.int32(ma) + ib,
                   jnp.int32(2 * ma + mb) + ib)

    def finish(mk, mv):
        mk = jnp.where(idx < new_count, mk, pad_value(mk.dtype))
        return SortShard(keys=mk, vals=mv, count=new_count), overflow

    def cut(v):
        m = ma + mb
        if m > cap:
            return v[:cap]
        if m < cap:
            fill = jnp.zeros((cap - m,) + v.shape[1:], v.dtype)
            return jnp.concatenate([v, fill])
        return v

    if not a.vals and a.keys.dtype == jnp.uint32:
        # keys-only u32: one single-operand u64 sort of (key << 32 | rank) —
        # measured at the plain-concat-sort lower bound on CPU, ~3x the
        # searchsorted/scatter formulation below
        comp = jnp.concatenate([
            (a.keys.astype(jnp.uint64) << 32) | ra.astype(jnp.uint64),
            (b.keys.astype(jnp.uint64) << 32) | rb.astype(jnp.uint64)])
        mk = (jnp.sort(comp) >> 32).astype(jnp.uint32)
        return finish(cut(mk), {})

    if all(v.ndim == 1 for v in a.vals.values()):
        # 1-D payloads ride a two-key lax.sort as extra operands
        keys = jnp.concatenate([a.keys, b.keys])
        rank = jnp.concatenate([ra, rb])
        ops = [keys, rank] + [jnp.concatenate([a.vals[k], b.vals[k]])
                              for k in a.vals]
        out = jax.lax.sort(ops, num_keys=2)
        mv = {k: cut(v) for k, v in zip(a.vals, out[2:])}
        return finish(cut(out[0]), mv)

    # general fallback (multi-dim payloads): compute each element's merged
    # position directly and scatter.  Position of a[i] = i + |{valid b
    # strictly less}| ('left' keeps equal-key b after a; b's pads — the
    # key-space max — only tie, never count).  Position of b[j] = j +
    # |{valid a less-or-equal}| ('right' counts equal-key a first; the
    # clamp to ca excludes a's pads when b[j] equals the pad word).
    nb = jnp.minimum(jnp.searchsorted(b.keys, a.keys, side="left"),
                     cb).astype(jnp.int32)
    na = jnp.minimum(jnp.searchsorted(a.keys, b.keys, side="right"),
                     ca).astype(jnp.int32)
    pos_a = jnp.where(ia < ca, ia + nb, jnp.int32(cap))   # cap ⇒ dropped
    pos_b = jnp.where(ib < cb, ib + na, jnp.int32(cap))
    mk = jnp.full((cap,), pad_value(a.keys.dtype), a.keys.dtype)
    mk = mk.at[pos_a].set(a.keys, mode="drop").at[pos_b].set(b.keys,
                                                             mode="drop")
    mv = {}
    for k in a.vals:
        va, vb = a.vals[k], b.vals[k]
        buf = jnp.zeros((cap,) + va.shape[1:], va.dtype)
        mv[k] = buf.at[pos_a].set(va, mode="drop").at[pos_b].set(vb,
                                                                 mode="drop")
    # overflowed elements were scattered at positions >= cap and dropped —
    # exactly the tail the lexsort path truncates
    return finish(mk, mv)


def resize(shard: SortShard, capacity: int):
    """Grow/shrink a shard's buffer (sorted, padded).  Returns (shard, overflow)."""
    if capacity == shard.capacity:
        return shard, jnp.int32(0)
    pad = shard.pad
    if capacity > shard.capacity:
        extra = capacity - shard.capacity
        keys = jnp.concatenate([shard.keys, jnp.full((extra,), pad, shard.keys.dtype)])
        vals = {k: jnp.concatenate([v, jnp.zeros((extra,) + v.shape[1:], v.dtype)])
                for k, v in shard.vals.items()}
        return SortShard(keys, vals, shard.count), jnp.int32(0)
    keys = shard.keys[:capacity]
    vals = {k: v[:capacity] for k, v in shard.vals.items()}
    overflow = jnp.maximum(shard.count - capacity, 0)
    return SortShard(keys, vals, jnp.minimum(shard.count, capacity)), overflow


def compact(shard: SortShard, keep_mask: jax.Array) -> SortShard:
    """Keep only elements where ``keep_mask`` (and valid); re-pack sorted."""
    keep = keep_mask & shard.valid_mask()
    pad = shard.pad
    keys = jnp.where(keep, shard.keys, pad)
    order = jnp.argsort(jnp.where(keep, jnp.int32(0), jnp.int32(1)), stable=True)
    keys = keys[order]
    vals = {k: v[order] for k, v in shard.vals.items()}
    return SortShard(keys, vals, jnp.sum(keep).astype(jnp.int32))
