"""repro.core — the paper's contribution: robust massively parallel sorting.

The library's internal word is 64 bits (the paper sorts 64-bit elements, and
the median-window lifting needs one value above the key space), so importing
this package enables ``jax_enable_x64``.  All model/framework code in this
repo declares explicit dtypes and is unaffected.
"""
import jax as _jax

_jax.config.update("jax_enable_x64", True)

from .api import SortConfig, psort, default_mesh  # noqa: E402,F401
from .external import ExternalPolicy          # noqa: E402,F401
from .types import (SortShard, make_shard, merge_shards, local_sort,  # noqa: E402,F401
                    key_to_uint, uint_to_key, LocalKernelPolicy,
                    local_kernels, set_local_kernels)
from .selection import select_algorithm, cost_select  # noqa: E402,F401
from .queries import (ResidentData, shard_data,       # noqa: E402,F401
                      select_rank, rank_of_key, percentile, top_k,
                      range_query, trace_query)
