"""Algorithm auto-selection from the α/β cost model (paper §IV, Table I),
re-calibrated for TPU v5e topology.

The paper's regime boundaries were driven by BlueGene/Q MPI startup costs.
Two things change on a TPU torus (DESIGN.md §2):

  * point-to-point hypercube steps map to collective-permutes: per-step
    cost α (launch + link latency);
  * fused collectives (all-gather / psum / all-to-all) are hardware-routed:
    they cost one launch *plus a torus-diameter pipeline latency*
    α_hop · p^(1/3) — they do NOT pay the paper's per-message αp, which
    moves the RAMS regime boundary down, but they are not free either.

The four-regime structure of the paper survives with shifted boundaries:
GatherM (very sparse) → RFIS (sparse) → RQuick (small) → RAMS (large).
Costs are per-sort seconds for 32-bit words.
"""
from __future__ import annotations

import math

ALPHA = 2.0e-6          # per collective-permute step (launch + hop)
ALPHA_C = 5.0e-6        # fused-collective launch
ALPHA_HOP = 1.5e-6      # per torus hop (pipeline fill of fused collectives)
BYTES_PER_WORD = 4
ICI_BW = 50e9           # bytes/s per link
BETA = BYTES_PER_WORD / ICI_BW
LOCAL_RATE = 2e9        # words/s local sort/merge/partition throughput
SLOT_OVERHEAD = 2.2     # static slot provisioning of the a2a exchanges


def _d(p):
    return math.log2(max(2, p))


def _hops(p):
    return p ** (1.0 / 3.0)         # 3-D torus diameter-ish


def _coll(p):
    return ALPHA_C + ALPHA_HOP * _hops(p)


def _lg(n):
    return math.log2(max(2, n))


def cost_gatherm(n, p):
    # binomial tree: d steps; root ingests all n words single-ported
    return ALPHA * _d(p) + BETA * n + n / LOCAL_RATE


def cost_allgatherm(n, p):
    # doubling: volume doubles per step → ~2n per PE; all PEs merge n words
    return ALPHA * _d(p) + BETA * 2 * n + n / LOCAL_RATE


def cost_rfis(n, p):
    d, sq = _d(p), math.sqrt(p)
    row = n / sq
    return (ALPHA * 2 * d                       # row+col gathers, routing
            + BETA * 3 * row                    # 2 gathers + delivery
            + (2 * row * _lg(row) + row) / LOCAL_RATE)  # merges + ranking


def cost_rquick(n, p):
    d = _d(p)
    npp = n / p
    return (ALPHA * (d * (d + 1) / 2)           # per-dim median butterflies
            + ALPHA * 2 * d                     # shuffle + exchanges
            + BETA * npp * (2 * d)              # shuffle + per-dim halves
            + (npp * _lg(n) + npp * d) / LOCAL_RATE)


def cost_rams(n, p, levels=None):
    npp = n / p
    d = _d(p)
    l = levels or max(1, min(3, round(d / 6)))
    k = p ** (1.0 / l)
    return ((3 * l + 1) * _coll(p)              # samples, hist, a2a / level
            + BETA * npp * (SLOT_OVERHEAD * l + 1)   # l exchanges + shuffle
            + (npp * _lg(n) + npp * l * _lg(k)) / LOCAL_RATE)


def cost_bitonic(n, p):
    d = _d(p)
    npp = n / p
    steps = d * (d + 1) / 2
    return ALPHA * steps + BETA * npp * steps + \
        (npp * _lg(n) + npp * steps) / LOCAL_RATE


def cost_ssort(n, p):
    npp = n / p
    # p-way splitters: every PE handles p sample words + p-slot exchange
    return (_coll(p) * 3 + BETA * (npp * SLOT_OVERHEAD + 16 * _lg(p) * p / p)
            + ALPHA_HOP * _hops(p)
            + (npp * _lg(n) + p) / LOCAL_RATE)


COSTS = {
    "gatherm": cost_gatherm,
    "rfis": cost_rfis,
    "rquick": cost_rquick,
    "rams": cost_rams,
}


def select_algorithm(n: int, p: int) -> str:
    """The paper's four-regime selection: argmin of the model costs.

    GatherM's output lives on one PE (no balance guarantee) → only
    eligible for very sparse inputs (§VII-A(1)).  RAMS needs dense input
    for its samples/slots to amortize.
    """
    cands = dict(COSTS)
    if n > max(8, p // 8):
        cands.pop("gatherm")
    if n <= 4 * p:
        cands.pop("rams", None)
    return min(cands, key=lambda a: cands[a](max(1, n), p))


def regime_table(p: int, exponents=range(-8, 24)):
    """n/p sweep → selected algorithm; used by tests and EXPERIMENTS.md."""
    rows = []
    for e in exponents:
        n = max(1, int(p * (2.0 ** e)))
        rows.append((e, n, select_algorithm(n, p)))
    return rows
