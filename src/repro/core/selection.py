"""Algorithm auto-selection from the α/β cost model (paper §IV, Table I),
parameterized by a measurable machine profile.

The paper's regime boundaries were driven by BlueGene/Q MPI startup costs.
Two things change on a TPU torus (DESIGN.md §2):

  * point-to-point hypercube steps map to collective-permutes: per-step
    cost α (launch + link latency);
  * fused collectives (all-gather / psum / all-to-all) are hardware-routed:
    they cost one launch *plus a torus-diameter pipeline latency*
    α_hop · p^(1/3) — they do NOT pay the paper's per-message αp, which
    moves the RAMS regime boundary down, but they are not free either.

The four-regime structure of the paper survives with shifted boundaries:
GatherM (very sparse) → RFIS (sparse) → RQuick (small) → RAMS (large).
Costs are per-sort seconds for 32-bit words.

The machine constants live in :class:`CostModel` — a profile of (α, α_c,
α_hop, β, local rate) with a JSON round-trip.  :data:`DEFAULT_MODEL` holds
the v5e priors that used to be module constants; ``benchmarks/calibrate.py``
*measures* a profile from counted collective traces + wall-clock on the sim
backend and writes ``profiles/<machine>.json``, which ``select_algorithm``
and ``psort(algorithm="auto", cost_model=...)`` accept in place of the
priors.  Regime tables for representative p are kept in ``EXPERIMENTS.md``
(regenerate with
``PYTHONPATH=src python benchmarks/calibrate.py --experiments-only``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional

BYTES_PER_WORD = 4


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Machine profile of the α/β cost model.

    alpha      — seconds per point-to-point step (collective-permute
                 launch + link latency);
    alpha_c    — seconds per fused-collective launch;
    alpha_hop  — seconds per torus hop (pipeline fill of fused collectives,
                 charged × p^(1/3));
    beta       — seconds per 32-bit word on the wire;
    local_rate — words/s of local sort/merge/partition throughput;
    slot_overhead — static slot provisioning factor of the a2a exchanges;
    meta       — free-form fit diagnostics (R², sweep grid, host, …).
    """

    name: str = "tpu-v5e-prior"
    alpha: float = 2.0e-6
    alpha_c: float = 5.0e-6
    alpha_hop: float = 1.5e-6
    beta: float = BYTES_PER_WORD / 50e9      # 50 GB/s per ICI link
    local_rate: float = 2e9
    slot_overhead: float = 2.2
    meta: Dict = dataclasses.field(default_factory=dict, compare=False)

    # -- derived ----------------------------------------------------------

    def coll(self, p: float) -> float:
        """Cost of one fused collective at axis size p."""
        return self.alpha_c + self.alpha_hop * _hops(p)

    # -- JSON round-trip --------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CostModel":
        raw = json.loads(text)
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(raw) - fields
        if unknown:
            raise ValueError(f"unknown CostModel fields: {sorted(unknown)}")
        return cls(**raw)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "CostModel":
        with open(path) as f:
            return cls.from_json(f.read())


DEFAULT_MODEL = CostModel()


def _d(p):
    return math.log2(max(2, p))


def _hops(p):
    return p ** (1.0 / 3.0)         # 3-D torus diameter-ish


def _lg(n):
    return math.log2(max(2, n))


def cost_gatherm(n, p, model: CostModel = DEFAULT_MODEL):
    # binomial tree: d steps; root ingests all n words single-ported
    m = model
    return m.alpha * _d(p) + m.beta * n + n / m.local_rate


def cost_allgatherm(n, p, model: CostModel = DEFAULT_MODEL):
    # doubling: volume doubles per step → ~2n per PE; all PEs merge n words
    m = model
    return m.alpha * _d(p) + m.beta * 2 * n + n / m.local_rate


def cost_rfis(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    d, sq = _d(p), math.sqrt(p)
    row = n / sq
    return (m.alpha * 2 * d                     # row+col gathers, routing
            + m.beta * 3 * row                  # 2 gathers + delivery
            + (2 * row * _lg(row) + row) / m.local_rate)  # merges + ranking


def cost_rquick(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    d = _d(p)
    npp = n / p
    return (m.alpha * (d * (d + 1) / 2)         # per-dim median butterflies
            + m.alpha * 2 * d                   # shuffle + exchanges
            + m.beta * npp * (2 * d)            # shuffle + per-dim halves
            + (npp * _lg(n) + npp * d) / m.local_rate)


def cost_rams(n, p, levels=None, model: CostModel = DEFAULT_MODEL):
    m = model
    npp = n / p
    d = _d(p)
    l = levels or max(1, min(3, round(d / 6)))
    k = p ** (1.0 / l)
    return ((3 * l + 1) * m.coll(p)             # samples, hist, a2a / level
            + m.beta * npp * (m.slot_overhead * l + 1)  # l exchanges + shuffle
            + (npp * _lg(n) + npp * l * _lg(k)) / m.local_rate)


def cost_bitonic(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    d = _d(p)
    npp = n / p
    steps = d * (d + 1) / 2
    return m.alpha * steps + m.beta * npp * steps + \
        (npp * _lg(n) + npp * steps) / m.local_rate


def cost_ssort(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    npp = n / p
    # p-way splitter selection: 16·lg p samples per PE are all-gathered, so
    # every PE receives a Θ(p log p)-word sample volume — the term that
    # makes single-level sample sort need n = Ω(p²/log p) to be efficient
    # (paper §VII).  Each PE also scans the p-sized splitter set locally.
    return (m.coll(p) * 3 + m.beta * (npp * m.slot_overhead + 16 * _lg(p) * p)
            + m.alpha_hop * _hops(p)
            + (npp * _lg(n) + p) / m.local_rate)


COSTS = {
    "gatherm": cost_gatherm,
    "rfis": cost_rfis,
    "rquick": cost_rquick,
    "rams": cost_rams,
}


def select_algorithm(n: int, p: int,
                     model: Optional[CostModel] = None) -> str:
    """The paper's four-regime selection: argmin of the model costs.

    GatherM's output lives on one PE (no balance guarantee) → only
    eligible for very sparse inputs (§VII-A(1)).  RAMS needs dense input
    for its samples/slots to amortize.  ``model`` defaults to the prior
    profile; pass ``CostModel.load("profiles/<machine>.json")`` to select
    with measured constants.
    """
    m = model if model is not None else DEFAULT_MODEL
    cands = dict(COSTS)
    if n > max(8, p // 8):
        cands.pop("gatherm")
    if n <= 4 * p:
        cands.pop("rams", None)
    return min(cands, key=lambda a: cands[a](max(1, n), p, model=m))


def regime_table(p: int, exponents=range(-8, 24),
                 model: Optional[CostModel] = None):
    """n/p sweep → selected algorithm; used by tests and EXPERIMENTS.md."""
    rows = []
    for e in exponents:
        n = max(1, int(p * (2.0 ** e)))
        rows.append((e, n, select_algorithm(n, p, model=model)))
    return rows
