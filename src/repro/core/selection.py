"""Algorithm auto-selection from the α/β cost model (paper §IV, Table I),
parameterized by a measurable machine profile.

The paper's regime boundaries were driven by BlueGene/Q MPI startup costs.
Two things change on a TPU torus (DESIGN.md §2):

  * point-to-point hypercube steps map to collective-permutes: per-step
    cost α (launch + link latency);
  * fused collectives (all-gather / psum / all-to-all) are hardware-routed:
    they cost one launch *plus a torus-diameter pipeline latency*
    α_hop · p^(1/3) — they do NOT pay the paper's per-message αp, which
    moves the RAMS regime boundary down, but they are not free either.

The four-regime structure of the paper survives with shifted boundaries:
GatherM (very sparse) → RFIS (sparse) → RQuick (small) → RAMS (large).
Costs are per-sort seconds for 32-bit words.

The machine constants live in :class:`CostModel` — a profile of (α, α_c,
α_hop, β, local rate) with a JSON round-trip.  :data:`DEFAULT_MODEL` holds
the v5e priors that used to be module constants; ``benchmarks/calibrate.py``
*measures* a profile from counted collective traces + wall-clock on the sim
backend and writes ``profiles/<machine>.json``, which ``select_algorithm``
and ``psort(algorithm="auto", cost_model=...)`` accept in place of the
priors.  Regime tables for representative p are kept in ``EXPERIMENTS.md``
(regenerate with
``PYTHONPATH=src python benchmarks/calibrate.py --experiments-only``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional

BYTES_PER_WORD = 4


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Machine profile of the α/β cost model.

    alpha      — seconds per point-to-point step (collective-permute
                 launch + link latency);
    alpha_c    — seconds per fused-collective launch;
    alpha_hop  — seconds per torus hop (pipeline fill of fused collectives,
                 charged × p^(1/3));
    beta       — seconds per 32-bit word on the wire;
    local_rate — words/s of local sort/merge throughput;
    partition_rate — words/s of splitter-partition (classify + rank +
                 histogram) throughput; ``None`` in profiles that predate
                 the fused partition kernel → the ``part_rate`` property
                 falls back to ``local_rate``;
    slot_overhead — static slot provisioning factor of the a2a exchanges;
    io_beta    — seconds per 32-bit word across the host↔device link
                 (the external lane's streaming cost); ``None`` in profiles
                 that predate the external regime → the ``io_b`` property
                 falls back to a PCIe-class prior;
    overlap    — fraction of the host↔device traffic hidden behind compute
                 by the double-buffered copies (0 = fully exposed,
                 1 = fully hidden);
    meta       — free-form fit diagnostics (R², sweep grid, host, …).

    On a **hierarchical mesh** (inter-host × intra-host, see
    ``repro.core.comm.NestedCollectives``) the flat constants describe the
    slow *outer* axis; the three ``*_inner`` fields hold the fast
    intra-axis constants ``benchmarks/calibrate.py`` fits from a two-tier
    sweep (``None`` = same as the outer axis).  Intra-axis fused
    collectives pay no ``alpha_hop`` pipeline fill — only the outer-axis
    level of a nested RAMS is charged ``alpha_hop`` + the slow-link
    ``beta`` (cf. the multi-level scheme of arXiv 1410.6754).
    """

    name: str = "tpu-v5e-prior"
    alpha: float = 2.0e-6
    alpha_c: float = 5.0e-6
    alpha_hop: float = 1.5e-6
    beta: float = BYTES_PER_WORD / 50e9      # 50 GB/s per ICI link
    local_rate: float = 2e9
    partition_rate: Optional[float] = None
    slot_overhead: float = 2.2
    alpha_inner: Optional[float] = None      # intra-axis p2p step
    alpha_c_inner: Optional[float] = None    # intra-axis fused launch
    beta_inner: Optional[float] = None       # intra-axis s/word
    io_beta: Optional[float] = None          # host↔device s/word
    overlap: float = 0.0                     # copy/compute overlap fraction
    meta: Dict = dataclasses.field(default_factory=dict, compare=False)

    def __post_init__(self):
        # reject out-of-range profiles at load time instead of clamping at
        # every cost evaluation — a fit that lands outside [0, 1] is a
        # calibration bug, not a value to silently repair
        if not (0.0 <= self.overlap <= 1.0):
            raise ValueError(f"CostModel.overlap must be in [0, 1], got "
                             f"{self.overlap}")

    # -- derived ----------------------------------------------------------

    def coll(self, p: float) -> float:
        """Cost of one fused collective at axis size p."""
        return self.alpha_c + self.alpha_hop * _hops(p)

    @property
    def a_inner(self) -> float:
        return self.alpha if self.alpha_inner is None else self.alpha_inner

    @property
    def ac_inner(self) -> float:
        return self.alpha_c if self.alpha_c_inner is None \
            else self.alpha_c_inner

    @property
    def b_inner(self) -> float:
        return self.beta if self.beta_inner is None else self.beta_inner

    def coll_inner(self, p: float) -> float:
        """One fused collective on the fast intra axis: launch cost only —
        intra-host links pay no torus-diameter pipeline fill."""
        return self.ac_inner

    @property
    def part_rate(self) -> float:
        return self.local_rate if self.partition_rate is None \
            else self.partition_rate

    @property
    def io_b(self) -> float:
        """Host↔device seconds per word; PCIe-class prior when unmeasured."""
        return BYTES_PER_WORD / 16e9 if self.io_beta is None else self.io_beta

    # -- JSON round-trip --------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CostModel":
        raw = json.loads(text)
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(raw) - fields
        if unknown:
            raise ValueError(f"unknown CostModel fields: {sorted(unknown)}")
        return cls(**raw)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "CostModel":
        with open(path) as f:
            return cls.from_json(f.read())


DEFAULT_MODEL = CostModel()


def _d(p):
    return math.log2(max(2, p))


def _hops(p):
    return p ** (1.0 / 3.0)         # 3-D torus diameter-ish


def _lg(n):
    return math.log2(max(2, n))


def cost_gatherm(n, p, model: CostModel = DEFAULT_MODEL):
    # binomial tree: d steps; root ingests all n words single-ported
    m = model
    return m.alpha * _d(p) + m.beta * n + n / m.local_rate


def cost_allgatherm(n, p, model: CostModel = DEFAULT_MODEL):
    # doubling: volume doubles per step → ~2n per PE; all PEs merge n words
    m = model
    return m.alpha * _d(p) + m.beta * 2 * n + n / m.local_rate


def cost_rfis(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    d, sq = _d(p), math.sqrt(p)
    row = n / sq
    return (m.alpha * 2 * d                     # row+col gathers, routing
            + m.beta * 3 * row                  # 2 gathers + delivery
            + (2 * row * _lg(row) + row) / m.local_rate)  # merges + ranking


def cost_rquick(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    d = _d(p)
    npp = n / p
    return (m.alpha * (d * (d + 1) / 2)         # per-dim median butterflies
            + m.alpha * 2 * d                   # shuffle + exchanges
            + m.beta * npp * (2 * d)            # shuffle + per-dim halves
            + npp * _lg(n) / m.local_rate       # local sort
            + npp * d / m.part_rate)            # per-dim pivot partition


def cost_rams(n, p, levels=None, model: CostModel = DEFAULT_MODEL,
              mesh_shape=None):
    m = model
    npp = n / p
    d = _d(p)
    if mesh_shape is not None:
        return _cost_rams_nested(n, p, levels, m, mesh_shape)
    l = levels or max(1, min(3, round(d / 6)))
    k = p ** (1.0 / l)
    # the streamed exchange pipeline (comm.alltoall_stream) hides a measured
    # ``overlap`` fraction of every slotted a2a behind the incremental merge
    ov = 1.0 - m.overlap
    return ((3 * l + 1) * m.coll(p)             # samples, hist, a2a / level
            + m.beta * npp * (m.slot_overhead * l + 1) * ov  # exch + shuffle
            + npp * _lg(n) / m.local_rate       # local sort
            + npp * l * _lg(k) / m.part_rate)   # k-way partition per level


def _cost_rams_nested(n, p, levels, m: CostModel, mesh_shape):
    """Hierarchical RAMS on an (outer × inner) mesh: only the shuffle and
    the first (outer-axis) level cross the slow links — they alone are
    charged ``alpha_hop`` pipeline fill and the slow-link ``beta``; every
    later level runs inside an intra subcube at the inner-axis constants
    (the 1410.6754 multi-level argument for why deep hierarchies win)."""
    p_o, p_i = mesh_shape
    npp = n / p
    ov = 1.0 - m.overlap               # streamed-exchange discount (see flat)
    if p_o <= 1:                       # pure-intra: no slow-axis level
        l = levels or max(1, min(3, round(_d(p_i) / 6)))
        k = max(2.0, p_i ** (1.0 / l))
        return ((3 * l + 1) * m.coll_inner(p_i)
                + m.b_inner * npp * (m.slot_overhead * l + 1) * ov
                + npp * _lg(n) / m.local_rate
                + npp * l * _lg(k) / m.part_rate)
    l_i = 0 if p_i <= 1 or levels == 1 else \
        (max(1, levels - 1) if levels else
         max(1, min(3, round(_d(p_i) / 6))))
    l = 1 + l_i
    # shuffle + level 0 span the whole mesh: one slow-axis stage plus one
    # intra stage each (the NestedCollectives decomposition)
    outer = (4 * m.coll(p) + 4 * m.coll_inner(p_i)
             + m.beta * npp * (m.slot_overhead + 1) * ov
             + m.b_inner * npp * (m.slot_overhead + 1) * ov)
    inner = (3 * l_i * m.coll_inner(p_i)
             + m.b_inner * npp * m.slot_overhead * l_i * ov)
    k = max(2.0, p ** (1.0 / l))
    local = npp * _lg(n) / m.local_rate + npp * l * _lg(k) / m.part_rate
    return outer + inner + local


def cost_bitonic(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    d = _d(p)
    npp = n / p
    steps = d * (d + 1) / 2
    return m.alpha * steps + m.beta * npp * steps + \
        (npp * _lg(n) + npp * steps) / m.local_rate


def cost_ssort(n, p, model: CostModel = DEFAULT_MODEL):
    m = model
    npp = n / p
    # p-way splitter selection: 16·lg p samples per PE are all-gathered, so
    # every PE receives a Θ(p log p)-word sample volume — the term that
    # makes single-level sample sort need n = Ω(p²/log p) to be efficient
    # (paper §VII).  Each PE also scans the p-sized splitter set locally.
    # Only the slotted data exchange streams — the sample gather does not.
    return (m.coll(p) * 3
            + m.beta * (npp * m.slot_overhead * (1.0 - m.overlap)
                        + 16 * _lg(p) * p)
            + m.alpha_hop * _hops(p)
            + npp * _lg(n) / m.local_rate       # local sort
            + p / m.part_rate)                  # p-way splitter scan


def cost_external(n, p, budget, model: CostModel = DEFAULT_MODEL):
    """Two-pass out-of-core sort of n/p words through a ``budget``-word
    device window (arXiv 0910.2582's pass structure on one device each):

      * every element crosses the host↔device link ~3× per pass (in, out,
        and once more through the merge's chunk staging) — 6·n/p words of
        streaming traffic, discounted by the measured ``overlap`` the
        double-buffered copies achieve (cf. arXiv 1410.6754);
      * R run-formation launches plus the splitter fit and the merge
        barrier cost one fused collective each;
      * one all-to-all per pass moves the slot-provisioned run slices;
      * the device sorts each window twice (runs, then merged chunks) and
        classifies every element against p splitters per pass.
    """
    m = model
    npp = max(1.0, n / p)
    budget = max(1, budget)
    runs = max(1.0, math.ceil(npp / budget))
    io = 6 * npp * m.io_b * (1.0 - m.overlap)   # range-checked in __post_init__
    coll = (runs + 2) * m.coll(p)
    wire = m.beta * npp * m.slot_overhead
    local = 2 * npp * _lg(min(npp, budget)) / m.local_rate
    classify = 2 * npp * _lg(p) / m.part_rate
    return io + coll + wire + local + classify


def cost_select(n, p, query: str = "percentile", batch: int = 1,
                k: Optional[int] = None, bits: int = 32,
                model: CostModel = DEFAULT_MODEL):
    """Cost of answering a ``batch`` of queries via the selection fast
    path of ``core/queries.py`` — i.e. *without* sorting.

    ``rank_of_key`` / ``range_query`` are pure counting: one fused psum
    over per-PE ``searchsorted`` ranks.  ``percentile`` / ``top_k`` run
    the exact rank selection: one §III-B butterfly window (d p2p steps),
    then ``ceil(bits/4)`` static refinement rounds of (sketch all_gather
    + count psum) with ~32 candidates × batch binary searches against the
    resident shard each, plus a verify psum; top-k adds the local tail
    extraction (≤ k words per PE).  Every term is O(polylog) in n — the
    crossover against :data:`COSTS` is what makes the fast path a *regime*
    rather than an always-win.
    """
    m = model
    npp = max(1.0, n / p)
    search = _lg(npp) / m.local_rate            # one binary search
    if query in ("rank_of_key", "range_query"):
        nq = batch * (2 if query == "range_query" else 1)
        return m.coll(p) + nq * search
    if query not in ("percentile", "top_k"):
        raise ValueError(f"cost_select: unknown query kind {query!r}")
    rounds = -(-bits // 4)                      # queries.n_rounds
    ncand = 32 + 16                             # grid+sketch, window round 0
    cost = (m.alpha * _d(p)                     # butterfly rank window
            + (2 * rounds + 1) * m.coll(p)      # gather+psum / round, verify
            + rounds * ncand * batch * search   # candidate ranking
            + rounds * 16 * batch * p * _lg(16 * p) / m.local_rate)  # sketch
    if query == "top_k":
        cost += batch * (k or 16) / m.local_rate    # tail extraction
    return cost


COSTS = {
    "gatherm": cost_gatherm,
    "rfis": cost_rfis,
    "rquick": cost_rquick,
    "rams": cost_rams,
}

QUERY_KINDS = ("sort", "top_k", "rank_of_key", "percentile", "range_query")


def select_algorithm(n: int, p: Optional[int] = None,
                     model: Optional[CostModel] = None,
                     levels: Optional[int] = None,
                     mesh_shape=None, budget: Optional[int] = None,
                     query: Optional[str] = None, batch: int = 1,
                     k: Optional[int] = None, bits: int = 32,
                     config=None) -> str:
    """The paper's four-regime selection: argmin of the model costs.

    GatherM's output lives on one PE (no balance guarantee) → only
    eligible for very sparse inputs (§VII-A(1)).  RAMS needs dense input
    for its samples/slots to amortize.  ``model`` defaults to the prior
    profile; pass ``CostModel.load("profiles/<machine>.json")`` to select
    with measured constants.  ``levels`` / ``mesh_shape`` parameterize the
    RAMS candidate the way :func:`repro.core.api.psort` would run it
    (nested meshes charge slow-axis constants for the outer level only).

    Selection is a pure function of (n, p, model), so the fault-tolerant
    ``psort(..., fault_policy=...)`` driver re-consults it after every
    exclude-and-rescale: shrinking p moves the (n, p) point across the
    regime map, and a sort that started as e.g. RAMS at large p may
    legitimately restart as RQuick at the reduced extent.

    ``budget`` (device words per PE) adds the fifth, external regime: when
    the shard no longer fits on the device the in-core candidates are not
    runnable at all, so any n/p above the budget selects "external"; below
    it the budget only matters through the crossover the cost model already
    encodes (streaming traffic vs. in-core wire volume).

    ``query`` adds the serving dimension (``core/queries.py``): for a
    non-``"sort"`` query kind the sort-free selection path
    (:func:`cost_select`, parameterized by ``batch``/``k``/``bits``)
    competes against answering off a full sort — the comparison charges
    the *entire* sort to the query batch, the right call for one-shot
    data; an amortizing service keeps sorted answers resident and makes
    its own policy (see ``launch/sort_serve.py``).  Returns
    ``"selection"`` when the fast path wins, else the sort regime's name.

    ``config`` (a :class:`repro.core.api.SortConfig`, duck-typed to avoid
    the import cycle) fills any of p / model / levels / mesh_shape /
    budget that were not passed directly — the same defaults ``psort``
    itself would consult for that config.
    """
    if config is not None:
        p = p if p is not None else config.p
        model = model if model is not None else config.cost_model
        levels = levels if levels is not None else config.levels
        mesh_shape = mesh_shape if mesh_shape is not None \
            else config.mesh_shape
        if budget is None and config.external is not None:
            budget = config.external.budget
    if p is None and mesh_shape is not None:
        p = int(mesh_shape[0]) * int(mesh_shape[1])
    if p is None:
        raise TypeError("select_algorithm() needs p — directly or via "
                        "config=SortConfig(p=... | mesh_shape=...)")
    m = model if model is not None else DEFAULT_MODEL
    if query is not None and query != "sort":
        if query not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {query!r}; "
                             f"know {QUERY_KINDS}")
        algo = select_algorithm(n, p, model=m, levels=levels,
                                mesh_shape=mesh_shape, budget=budget)
        c_sort = cost_external(n, p, budget, model=m) \
            if algo == "external" else \
            (cost_rams(max(1, n), p, levels=levels, model=m,
                       mesh_shape=mesh_shape) if algo == "rams"
             else COSTS[algo](max(1, n), p, model=m))
        c_sel = cost_select(n, p, query=query, batch=batch, k=k, bits=bits,
                            model=m)
        return "selection" if c_sel < c_sort else algo
    if budget is not None and n / p > budget:
        return "external"
    cands = dict(COSTS)
    if n > max(8, p // 8):
        cands.pop("gatherm")
    if n <= 4 * p:
        cands.pop("rams", None)

    def cost(a):
        if a == "rams":
            return cost_rams(max(1, n), p, levels=levels, model=m,
                             mesh_shape=mesh_shape)
        return cands[a](max(1, n), p, model=m)

    return min(cands, key=cost)


def regime_table(p: int, exponents=range(-8, 24),
                 model: Optional[CostModel] = None,
                 levels: Optional[int] = None, mesh_shape=None,
                 budget: Optional[int] = None):
    """n/p sweep → selected algorithm; used by tests and EXPERIMENTS.md.
    ``levels`` / ``mesh_shape`` / ``budget`` forward to the costs exactly
    as :func:`select_algorithm` does."""
    rows = []
    for e in exponents:
        n = max(1, int(p * (2.0 ** e)))
        rows.append((e, n, select_algorithm(n, p, model=model, levels=levels,
                                            mesh_shape=mesh_shape,
                                            budget=budget)))
    return rows
