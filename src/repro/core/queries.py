"""Distributed selection & query primitives — the sort-free fast paths.

Most queries against a sorted-data service do not need the full sort:
``top_k``, ``rank_of_key``, ``percentile`` and ``range_query`` only need
*one* order statistic (plus a small extraction), and the paper's own
machinery answers them directly:

  * the §III-B **single-reduction median window** (``core/median.py``,
    generalized to arbitrary rank fractions by
    :func:`repro.core.median.butterfly_rank_window`) seeds splitter
    candidates around the target rank in one ``log p`` butterfly;
  * the **multi-level splitter sketch** of Practical Massively Parallel
    Sorting (arXiv 1410.6754; ``rams.quantile_splitters``) pools
    deterministic stride samples of each PE's active key window into
    refined candidates — one fused ``all_gather`` per round.

Exactness does not rest on either estimator: every round *counts* each
candidate with one fused ``psum`` of per-PE ``searchsorted`` ranks, so a
candidate ``c`` with ``#{x < c} < t <= #{x <= c}`` **is** the rank-``t``
element (duplicates — the Zero / DeterDupl distributions — terminate in
one round this way), and otherwise the counts bracket the answer into a
strictly smaller key interval.  A deterministic 16-point grid over the
active interval guarantees ≥ 4 bits of interval shrink per round, so
``ceil(bits/4)`` static rounds always pin the answer exactly — selection
output is **bitwise equal** to indexing the full-sort oracle, at cost
O(n/p · rounds · log cap  +  coll · (rounds + log p)) with *no*
all-to-all and no data movement.

Queries run against a :class:`ResidentData` — the dataset sharded over p
PEs with each shard locally sorted (built once by :func:`shard_data`) —
and are **batched**: every primitive takes a (B,) vector of query
parameters and answers the whole micro-batch with the same collective
schedule (the continuous-batching frontend in
``repro/launch/sort_serve.py`` rides on this).  Both execution backends
of ``psort`` are supported and bitwise-identical.  Collectives are traced
under ``query:*`` phase tags (:func:`repro.core.comm.tagged`) so counted
traces attribute per-phase launches; :func:`trace_query` counts a query's
collectives without executing a FLOP.

>>> import numpy as np
>>> from repro.core.queries import shard_data, top_k, rank_of_key
>>> data = shard_data(np.array([5, 3, 1, 4, 2, 9, 8, 6], np.int32), p=4)
>>> np.asarray(top_k(data, 3, backend="sim"))
array([6, 8, 9], dtype=int32)
>>> rank_of_key(data, 5, backend="sim")     # (#keys < 5, #keys <= 5)
(np.int64(4), np.int64(5))
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.compat import shard_map

from . import comm
from .median import butterfly_rank_window
from .rams import quantile_splitters
from .types import SortShard, key_to_uint, pad_value, uint_to_key

GRID = 16       # deterministic interval-grid candidates per round
SKETCH = 16     # pooled stride-sketch candidates per round
WINDOW_K = 16   # butterfly rank-window size (u32 key space only)

QUERY_KINDS = ("sort", "top_k", "rank_of_key", "percentile", "range_query")


def n_rounds(bits: int) -> int:
    """Static refinement rounds: the 16-point grid splits the active
    interval into ≥ 17 parts, so each round resolves ≥ 4 key bits."""
    return -(-bits // 4)


# ---------------------------------------------------------------------------
# Resident data: the sharded, locally-sorted dataset queries run against
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResidentData:
    """A dataset laid out for repeated queries: (p, cap) unsigned key rows
    (PE-major, exactly ``psort``'s input layout), each row locally sorted
    ascending with the key-space maximum as tail padding, plus per-row
    valid counts.  Local sorting is the one-time ingest cost that makes
    every per-candidate rank a ``searchsorted`` instead of a scan."""

    keys: jax.Array          # (p, cap) uint32/uint64, rows sorted ascending
    counts: jax.Array        # (p,) int32
    n: int
    orig_dtype: np.dtype

    @property
    def p(self) -> int:
        return self.keys.shape[0]

    @property
    def cap(self) -> int:
        return self.keys.shape[1]

    @property
    def bits(self) -> int:
        return jnp.dtype(self.keys.dtype).itemsize * 8


def shard_data(keys, p: int) -> ResidentData:
    """Shard a host array over p PEs and locally sort each shard."""
    keys = jnp.asarray(keys)
    if keys.ndim != 1:
        raise ValueError(f"resident data must be 1-D; got {keys.shape}")
    if p < 1 or p & (p - 1):
        raise ValueError(f"p={p} must be a power of two (hypercube layout)")
    n = keys.shape[0]
    u = key_to_uint(keys)
    per = -(-max(n, 1) // p)
    pad = pad_value(u.dtype)
    flat = jnp.full((p * per,), pad, u.dtype).at[:n].set(u)
    rows = jnp.sort(flat.reshape(p, per), axis=1)
    row_counts = jnp.minimum(jnp.maximum(n - per * jnp.arange(p), 0),
                             per).astype(jnp.int32)
    return ResidentData(rows, row_counts, n, np.dtype(keys.dtype))


# ---------------------------------------------------------------------------
# Per-PE SPMD bodies (collectives via repro.core.comm; backend-agnostic)
# ---------------------------------------------------------------------------


def _local_ranks(row, count, cands):
    """(#row < c, #row <= c) for each candidate, restricted to the valid
    prefix.  ``row`` is sorted with max-valued padding, so clipping the
    searchsorted position to ``count`` is exact even when real keys equal
    the pad word (the count smallest entries are exactly the valid ones)."""
    lt = jnp.minimum(jnp.searchsorted(row, cands, side="left"), count)
    le = jnp.minimum(jnp.searchsorted(row, cands, side="right"), count)
    return lt.astype(jnp.int64), le.astype(jnp.int64)


def _counts_body(axis_name: str):
    """body(row, count, cands (B,)) -> global (n_lt, n_le), each (B,)."""

    def body(row, count, cands):
        with comm.tagged("query:counts"):
            lt, le = _local_ranks(row, count, cands)
            g = comm.psum(jnp.stack([lt, le]), axis_name)
        return g[0], g[1]

    return body


def _sketch_candidates(row, count, lo, hi, axis_name):
    """SKETCH pooled candidates per query from the active key windows.

    Each PE contributes a deterministic stride sketch of its local keys
    inside [lo, hi] (the 1410.6754 sample scheme, as in the external
    lane's run sketches); one fused all_gather pools them and
    ``rams.quantile_splitters`` picks evenly spaced order statistics.
    """
    B = lo.shape[0]
    pad = pad_value(row.dtype)
    a = jnp.minimum(jnp.searchsorted(row, lo, side="left"), count)   # (B,)
    b = jnp.minimum(jnp.searchsorted(row, hi, side="right"), count)
    ln = (b - a).astype(jnp.int64)
    jj = jnp.arange(SKETCH, dtype=jnp.int64)
    pos = a[:, None].astype(jnp.int64) + ((2 * jj[None] + 1) * ln[:, None]) \
        // (2 * SKETCH)
    samp = jnp.take(row, jnp.clip(pos, 0, row.shape[0] - 1))         # (B, S)
    samp = jnp.where(ln[:, None] > 0, samp, pad)   # empty window → invalid
    g = comm.all_gather(samp, axis_name)                             # (p,B,S)
    pooled = jnp.sort(jnp.moveaxis(g, 0, 1).reshape(B, -1), axis=1)
    sk = jax.vmap(lambda s: quantile_splitters(s, SKETCH + 1, invalid=pad)
                  )(pooled)                                          # (B, S)
    sk = jnp.where(sk == pad, lo[:, None], sk)
    return jnp.clip(sk, lo[:, None], hi[:, None])


def _grid_candidates(lo, hi):
    """GRID deterministic probes splitting [lo, hi] into ≥ 17 parts; when
    the interval is narrower than the grid the probes enumerate it
    exhaustively (min(j·max(step,1), span)), so narrow intervals resolve
    in one round."""
    udt = lo.dtype
    span = hi - lo                                        # (B,) unsigned
    step = span // np.asarray(GRID + 1).astype(udt)
    j = jnp.arange(1, GRID + 1, dtype=udt)
    off = jnp.minimum(j[None] * jnp.maximum(step, np.asarray(1).astype(udt)
                                            )[:, None], span[:, None])
    return lo[:, None] + off                              # (B, GRID)


_LO64 = np.uint64(0)
_HI64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _window_candidates(row, count, fracs, axis_name, p):
    """Round-0 candidates from the §III-B butterfly rank window (u32 key
    space only — the lifted u64 window has no headroom above u64 keys).
    Fillers (±inf) map to 0: a harmless duplicate probe, never a wrong
    answer — the counting round decides."""
    dims = list(range(p.bit_length() - 1))
    sh = SortShard(keys=row, vals={}, count=count)
    with comm.tagged("query:window"):
        w = butterfly_rank_window(sh, axis_name, p, dims, WINDOW_K, fracs)
    filler = (w == _LO64) | (w == _HI64)
    return jnp.where(filler, np.uint64(1), w).astype(jnp.uint32) - \
        jnp.where(filler, np.uint32(0), np.uint32(1))


def _select_body(axis_name: str, p: int, bits: int, use_window: bool):
    """body(row, count, ranks (B,) int64 1-indexed, fracs (B,) f64)
    -> (ans (B,) unsigned, n_lt (B,), n_le (B,)) — exact global order
    statistics, identical on every PE."""
    R = n_rounds(bits)

    def body(row, count, ranks, fracs):
        B = ranks.shape[0]
        udt = row.dtype
        umax = pad_value(udt)
        lo = jnp.zeros((B,), udt)
        hi = jnp.full((B,), umax, udt)
        done = jnp.zeros((B,), bool)
        ans = jnp.zeros((B,), udt)
        wc = _window_candidates(row, count, fracs, axis_name, p) \
            if use_window else None
        t = ranks[:, None]
        for r in range(R):
            with comm.tagged(f"query:round{r}"):
                parts = [_grid_candidates(lo, hi),
                         _sketch_candidates(row, count, lo, hi, axis_name)]
                if r == 0 and wc is not None:
                    parts.append(wc)
                cands = jnp.concatenate(parts, axis=1)          # (B, nb)
                lt, le = _local_ranks(row, count, cands)
                g = comm.psum(jnp.stack([lt, le]), axis_name)
            glt, gle = g[0], g[1]
            # a candidate straddling the rank IS the answer (all straddling
            # candidates share one value — counts separate distinct keys)
            hit = (glt < t) & (t <= gle)
            anyhit = jnp.any(hit, axis=1)
            cand_ans = jnp.max(jnp.where(hit, cands, jnp.zeros((), udt)),
                               axis=1)
            # otherwise every candidate brackets: gle < t ⇒ answer > c,
            # glt >= t ⇒ answer < c (c=0 / c=umax can never fire these)
            lo_new = jnp.max(jnp.where(gle < t, cands + np.asarray(1, udt),
                                       lo[:, None]), axis=1)
            hi_new = jnp.min(jnp.where(glt >= t, cands - np.asarray(1, udt),
                                       hi[:, None]), axis=1)
            upd = ~done
            ans = jnp.where(upd & anyhit, cand_ans, ans)
            done = done | (upd & anyhit)
            lo = jnp.where(done, lo, jnp.maximum(lo, lo_new))
            hi = jnp.where(done, hi, jnp.minimum(hi, hi_new))
            pinched = ~done & (lo >= hi)
            ans = jnp.where(pinched, lo, ans)
            done = done | pinched
        ans = jnp.where(done, ans, lo)
        with comm.tagged("query:verify"):
            lt, le = _local_ranks(row, count, ans)
            g = comm.psum(jnp.stack([lt, le]), axis_name)
        return ans, g[0], g[1]

    return body


def _extract_gt(row, count, theta, k_cap: int):
    """Per-PE tail segment of elements strictly above theta (B,) — at most
    k_cap each, since globally fewer than k exceed the rank-(n-k+1) key."""
    pad = pad_value(row.dtype)
    s = jnp.minimum(jnp.searchsorted(row, theta, side="right"), count)
    ln = (count - s).astype(jnp.int32)                       # (B,)
    jj = jnp.arange(k_cap, dtype=jnp.int32)
    pos = jnp.clip(s[:, None] + jj[None], 0, row.shape[0] - 1)
    vals = jnp.take(row, pos)                                # (B, k_cap)
    vals = jnp.where(jj[None] < ln[:, None], vals, pad)
    return vals, ln


def _topk_body(axis_name: str, p: int, bits: int, use_window: bool,
               k_cap: int):
    sel = _select_body(axis_name, p, bits, use_window)

    def body(row, count, ranks, fracs):
        ans, glt, gle = sel(row, count, ranks, fracs)
        vals, ln = _extract_gt(row, count, ans, k_cap)
        return ans, glt, gle, vals, ln

    return body


# ---------------------------------------------------------------------------
# Backend runners (sim = vmapped PEs, shard_map = real devices) + jit caches
# ---------------------------------------------------------------------------

BACKENDS = ("sim", "shard_map")


def _tile(x, p):
    return jnp.broadcast_to(x, (p,) + x.shape)


@partial(jax.jit, static_argnames=("axis", "p"))
def _counts_sim_jit(keys2d, counts, cands, axis, p):
    body = _counts_body(axis)
    return comm.sim_map(body, axis, p)(keys2d, counts, _tile(cands, p))


@partial(jax.jit, static_argnames=("axis", "p", "mesh"))
def _counts_shard_jit(keys2d, counts, cands, mesh, axis, p):
    body = _counts_body(axis)

    def blk(k, c, q):
        out = body(k[0], c[0], q[0])
        return tuple(o[None] for o in out)

    return shard_map(blk, mesh=mesh, in_specs=(P(axis),) * 3,
                     out_specs=(P(axis),) * 2)(keys2d, counts,
                                               _tile(cands, p))


@partial(jax.jit, static_argnames=("axis", "p", "bits", "use_window"))
def _select_sim_jit(keys2d, counts, ranks, fracs, axis, p, bits, use_window):
    body = _select_body(axis, p, bits, use_window)
    return comm.sim_map(body, axis, p)(keys2d, counts, _tile(ranks, p),
                                       _tile(fracs, p))


@partial(jax.jit, static_argnames=("axis", "p", "bits", "use_window", "mesh"))
def _select_shard_jit(keys2d, counts, ranks, fracs, mesh, axis, p, bits,
                      use_window):
    body = _select_body(axis, p, bits, use_window)

    def blk(k, c, r, f):
        out = body(k[0], c[0], r[0], f[0])
        return tuple(o[None] for o in out)

    return shard_map(blk, mesh=mesh, in_specs=(P(axis),) * 4,
                     out_specs=(P(axis),) * 3)(keys2d, counts,
                                               _tile(ranks, p),
                                               _tile(fracs, p))


@partial(jax.jit, static_argnames=("axis", "p", "bits", "use_window",
                                   "k_cap"))
def _topk_sim_jit(keys2d, counts, ranks, fracs, axis, p, bits, use_window,
                  k_cap):
    body = _topk_body(axis, p, bits, use_window, k_cap)
    return comm.sim_map(body, axis, p)(keys2d, counts, _tile(ranks, p),
                                       _tile(fracs, p))


@partial(jax.jit, static_argnames=("axis", "p", "bits", "use_window",
                                   "k_cap", "mesh"))
def _topk_shard_jit(keys2d, counts, ranks, fracs, mesh, axis, p, bits,
                    use_window, k_cap):
    body = _topk_body(axis, p, bits, use_window, k_cap)

    def blk(k, c, r, f):
        out = body(k[0], c[0], r[0], f[0])
        return tuple(o[None] for o in out)

    return shard_map(blk, mesh=mesh, in_specs=(P(axis),) * 4,
                     out_specs=(P(axis),) * 5)(keys2d, counts,
                                               _tile(ranks, p),
                                               _tile(fracs, p))


def _mesh_for(data: ResidentData, mesh, axis: str):
    if mesh is not None:
        return mesh
    from .api import default_mesh
    return default_mesh(data.p, axis)


def _check_backend(backend: str):
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")


# ---------------------------------------------------------------------------
# Host-level query API
# ---------------------------------------------------------------------------


def _as_batch(x, dtype=None):
    a = np.asarray(x) if dtype is None else np.asarray(x, dtype)
    scalar = a.ndim == 0
    return np.atleast_1d(a), scalar


def select_rank(data: ResidentData, ranks, *, backend: str = "sim",
                axis: str = "sort", mesh=None, window: bool = True):
    """Exact keys of the given global ranks (1-indexed, ascending order).

    Returns ``(values, n_lt, n_le)`` where ``values[b]`` is bitwise equal
    to ``np.sort(keys)[ranks[b] - 1]`` and the counts are the number of
    elements strictly below / at-or-below it.
    """
    _check_backend(backend)
    ranks_np, scalar = _as_batch(ranks, np.int64)
    if data.n < 1:
        raise ValueError("select_rank on empty resident data")
    if (ranks_np < 1).any() or (ranks_np > data.n).any():
        raise ValueError(f"ranks must lie in [1, n={data.n}]; got {ranks_np}")
    fracs = (ranks_np - 1) / max(data.n - 1, 1)
    use_window = window and data.bits == 32 and data.p > 1
    if backend == "sim":
        ans, glt, gle = _select_sim_jit(
            data.keys, data.counts, jnp.asarray(ranks_np), jnp.asarray(fracs),
            axis, data.p, data.bits, use_window)
    else:
        mesh = _mesh_for(data, mesh, axis)
        ans, glt, gle = _select_shard_jit(
            data.keys, data.counts, jnp.asarray(ranks_np), jnp.asarray(fracs),
            mesh, axis, data.p, data.bits, use_window)
    ans = np.asarray(uint_to_key(ans[0], data.orig_dtype))
    glt, gle = np.asarray(glt[0]), np.asarray(gle[0])
    if scalar:
        return ans[0], glt[0], gle[0]
    return ans, glt, gle


def rank_of_key(data: ResidentData, keys, *, backend: str = "sim",
                axis: str = "sort", mesh=None):
    """Global ranks of the given key values (batched).

    Returns ``(n_lt, n_le)``: the number of resident elements strictly
    below / at-or-below each query key — i.e. ``np.searchsorted(sorted,
    key, "left")`` and ``..."right"`` of the full-sort oracle.
    """
    _check_backend(backend)
    k_np, scalar = _as_batch(keys, data.orig_dtype)
    u = key_to_uint(jnp.asarray(k_np))
    if backend == "sim":
        glt, gle = _counts_sim_jit(data.keys, data.counts, u, axis, data.p)
    else:
        mesh = _mesh_for(data, mesh, axis)
        glt, gle = _counts_shard_jit(data.keys, data.counts, u, mesh, axis,
                                     data.p)
    glt, gle = np.asarray(glt[0]), np.asarray(gle[0])
    if scalar:
        return glt[0], gle[0]
    return glt, gle


def percentile(data: ResidentData, q, *, backend: str = "sim",
               axis: str = "sort", mesh=None):
    """Exact percentile values (NumPy ``interpolation="lower"``): the
    element at sorted index ``floor(q/100 · (n-1))`` — never interpolated,
    so integer keys stay exact and the answer is bitwise equal to the
    full-sort oracle's."""
    q_np, scalar = _as_batch(q, np.float64)
    if (q_np < 0).any() or (q_np > 100).any():
        raise ValueError(f"percentiles must lie in [0, 100]; got {q_np}")
    ranks = np.floor(q_np / 100.0 * (data.n - 1)).astype(np.int64) + 1
    vals, _, _ = select_rank(data, ranks, backend=backend, axis=axis,
                             mesh=mesh)
    return vals[0] if scalar else vals


def top_k(data: ResidentData, k, *, backend: str = "sim",
          axis: str = "sort", mesh=None):
    """The k largest resident keys, ascending — bitwise equal to
    ``np.sort(keys)[-k:]``.

    One exact rank selection finds the threshold θ = rank n-k+1; each PE
    then contributes its (sorted, ≤ k long) tail of elements > θ, and the
    host closes the multiset with the deficit copies of θ itself (the
    tie-completion that makes the answer exact under duplicates).  With a
    (B,)-batch of k values returns a list of arrays.
    """
    _check_backend(backend)
    k_np, scalar = _as_batch(k, np.int64)
    if (k_np < 1).any() or (k_np > data.n).any():
        raise ValueError(f"k must lie in [1, n={data.n}]; got {k_np}")
    ranks = data.n - k_np + 1
    fracs = (ranks - 1) / max(data.n - 1, 1)
    k_cap = int(min(data.cap, k_np.max()))
    use_window = data.bits == 32 and data.p > 1
    if backend == "sim":
        ans, glt, gle, vals, ln = _topk_sim_jit(
            data.keys, data.counts, jnp.asarray(ranks), jnp.asarray(fracs),
            axis, data.p, data.bits, use_window, k_cap)
    else:
        mesh = _mesh_for(data, mesh, axis)
        ans, glt, gle, vals, ln = _topk_shard_jit(
            data.keys, data.counts, jnp.asarray(ranks), jnp.asarray(fracs),
            mesh, axis, data.p, data.bits, use_window, k_cap)
    theta = np.asarray(ans[0])                       # (B,) unsigned
    gle = np.asarray(gle[0])
    vals = np.asarray(vals)                          # (p, B, k_cap)
    ln = np.asarray(ln)                              # (p, B)
    outs = []
    for b in range(len(k_np)):
        above = np.concatenate([vals[pe, b, :ln[pe, b]]
                                for pe in range(data.p)])
        n_gt = data.n - gle[b]
        assert len(above) == n_gt, (len(above), n_gt)
        full = np.concatenate([np.full(k_np[b] - n_gt, theta[b],
                                       dtype=theta.dtype), above])
        outs.append(np.asarray(uint_to_key(jnp.asarray(np.sort(full)),
                                           data.orig_dtype)))
    return outs[0] if scalar else outs


def range_query(data: ResidentData, lo, hi, *, backend: str = "sim",
                axis: str = "sort", mesh=None):
    """Number of resident keys in the half-open interval [lo, hi) — equal
    to the oracle's ``searchsorted(sorted, hi, "left") -
    searchsorted(sorted, lo, "left")`` (0 when hi <= lo)."""
    _check_backend(backend)
    lo_np, scalar = _as_batch(lo, data.orig_dtype)
    hi_np, _ = _as_batch(hi, data.orig_dtype)
    if lo_np.shape != hi_np.shape:
        raise ValueError(f"lo/hi shape mismatch: {lo_np.shape} vs "
                         f"{hi_np.shape}")
    both = key_to_uint(jnp.concatenate([jnp.asarray(lo_np),
                                        jnp.asarray(hi_np)]))
    if backend == "sim":
        glt, _ = _counts_sim_jit(data.keys, data.counts, both, axis, data.p)
    else:
        mesh = _mesh_for(data, mesh, axis)
        glt, _ = _counts_shard_jit(data.keys, data.counts, both, mesh, axis,
                                   data.p)
    glt = np.asarray(glt[0])
    b = len(lo_np)
    cnt = np.maximum(glt[b:] - glt[:b], 0)
    return cnt[0] if scalar else cnt


# ---------------------------------------------------------------------------
# Counted traces (the measured counterpart of the cost model's query terms)
# ---------------------------------------------------------------------------


def trace_query(kind: str, n: int, p: int, *, batch: int = 1,
                dtype=np.uint32, k: Optional[int] = None) -> comm.CommTrace:
    """Count the collectives one batched query would launch, per PE.

    Like :func:`repro.core.api.trace_collectives` but for the selection
    fast paths: abstractly evaluates the per-PE query body (shapes only,
    no FLOPs) under a :class:`repro.core.comm.CountingCollectives`
    decorator.  Deterministic — EXPERIMENTS.md's mixed-query grid is
    generated from these.  ``kind="sort"`` delegates to the full-sort
    trace for comparison.

    >>> t = trace_query("rank_of_key", 1024, 8, batch=4)
    >>> t.summary()["counts"]
    {'psum': 1}
    >>> t.tags()
    ['query:counts']
    """
    if kind not in QUERY_KINDS:
        raise ValueError(f"unknown query kind {kind!r}; know {QUERY_KINDS}")
    if p < 1 or p & (p - 1):
        raise ValueError(f"p={p} must be a power of two")
    if kind == "sort":
        from .api import SortConfig, trace_collectives
        return trace_collectives(n, SortConfig(p=p))
    bits = np.dtype(dtype).itemsize * 8
    per = -(-max(n, 1) // p)
    use_window = bits == 32 and p > 1
    udt = jnp.uint32 if bits == 32 else jnp.uint64
    counter = comm.CountingCollectives(comm.SIM)
    if kind == "rank_of_key" or kind == "range_query":
        nc = batch if kind == "rank_of_key" else 2 * batch
        body = _counts_body("sort")
        args = (jax.ShapeDtypeStruct((p, per), udt),
                jax.ShapeDtypeStruct((p,), jnp.int32),
                jax.ShapeDtypeStruct((p, nc), udt))
    else:
        k_cap = int(min(per * p, k if k is not None else 16, per * p))
        if kind == "top_k":
            body = _topk_body("sort", p, bits, use_window, max(1, k_cap))
        else:
            body = _select_body("sort", p, bits, use_window)
        args = (jax.ShapeDtypeStruct((p, per), udt),
                jax.ShapeDtypeStruct((p,), jnp.int32),
                jax.ShapeDtypeStruct((p, batch), jnp.int64),
                jax.ShapeDtypeStruct((p, batch), jnp.float64))
    runner = comm.sim_map(body, "sort", p, impl=counter)
    jax.eval_shape(runner, *args)
    return counter.trace
