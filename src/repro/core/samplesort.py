"""Single-level p-way sample sort (SSort, paper §VII) — the classical
baseline that "delivers the data directly".  Θ(p) splitters, one exchange.

``robust=True`` prepends Helman et al.'s random redistribution (the paper's
§III-A folklore defense); without it, skewed instances overflow the static
slots — the SPMD manifestation of the paper's "very slow even for rather
large n/p" and the reason SSort needs n = Ω(p²/log p) to be efficient.

``oracle_splitters`` implements NS-SSort (Fig. 2d): skip the sampling phase
entirely and use externally supplied splitters — a lower bound for any
single-exchange algorithm.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .hypercube import _alltoall_route, alltoall_shuffle
from .rams import quantile_splitters
from .types import SortShard, local_sort, resize
from repro.kernels.partition import partition_buckets

_HI64 = np.uint64(0xFFFFFFFFFFFFFFFF)


class SSortResult(NamedTuple):
    shard: SortShard
    overflow: jax.Array


def samplesort(shard: SortShard, axis_name: str, p: int, *,
               seed: int = 0x550, robust: bool = True,
               sample_factor: int = 16, slot_factor: float = 2.0,
               oracle_splitters: Optional[jax.Array] = None,
               overlap: bool = False) -> SSortResult:
    cap = shard.capacity
    me = comm.axis_index(axis_name)
    overflow = jnp.int32(0)
    slot_cap = int(math.ceil(slot_factor * max(1.0, cap / p)
                             + 6 * math.sqrt(max(1.0, cap / p)) + 6))

    if robust:
        shard, ovf = alltoall_shuffle(shard, axis_name, p, seed,
                                      slot_cap=slot_cap, stream=overlap)
        overflow = overflow + ovf
        if not overlap:                     # streamed arrives sorted
            shard = local_sort(shard)
        # shrink the p·slot_cap shuffle buffer to 2× the working capacity
        # (full shrink would tighten the exchange slots; see rams.py)
        shard, ovf = resize(shard, min(shard.capacity, 2 * cap))
        overflow = overflow + ovf
    else:
        shard = local_sort(shard)

    if oracle_splitters is not None:
        splitters = jnp.asarray(oracle_splitters)
        assert splitters.shape[0] == p - 1
    else:
        # sample 16·log p per PE (paper's tuning), gather, pick p-1 quantiles
        s_per = max(1, sample_factor * max(1, int(math.log2(max(p, 2)))))
        key = jax.random.fold_in(jax.random.PRNGKey(seed), me)
        pos = jax.random.randint(key, (s_per,), 0, jnp.maximum(shard.count, 1))
        samp = shard.keys[pos].astype(jnp.uint64)
        samp = jnp.where((pos < shard.count), samp, _HI64)
        all_samp = jnp.sort(comm.all_gather(samp, axis_name, tiled=True))
        splitters = quantile_splitters(all_samp, p)

    # fused SSSS classify (#splitters ≤ key): the u64 splitters and the
    # zero-extended keys compare as (hi, lo) u32 planes lexicographically;
    # invalid entries (index ≥ count) go to the trash destination p
    keys64 = shard.keys.astype(jnp.uint64)
    dest, _, _ = partition_buckets(
        (keys64 >> np.uint64(32)).astype(jnp.uint32),
        keys64.astype(jnp.uint32),
        (splitters >> np.uint64(32)).astype(jnp.uint32),
        splitters.astype(jnp.uint32),
        n_buckets=p, count=shard.count, want_pos=False)
    out, ovf = _alltoall_route(shard, dest, axis_name, p, slot_cap,
                               stream=overlap)
    overflow = overflow + ovf
    if not overlap:                         # streamed arrives sorted
        out = local_sort(out)
    out, ovf2 = resize(out, cap)
    return SSortResult(out, overflow + ovf2)
