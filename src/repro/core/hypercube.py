"""Hypercube communication patterns on a named mesh axis.

The paper uses the hypercube design pattern (Algorithm 1) for everything:
all-gather-merge, reductions, random shuffling and routing.  On TPU the
pairwise ``i XOR 2^j`` exchange maps 1:1 onto ``jax.lax.ppermute`` with a
static permutation — a single collective-permute over ICI per step, which is
exactly the static-schedule analogue of the paper's point-to-point message.

All functions here must be called *inside* ``shard_map`` over ``axis_name``.
Subcube collectives need no communicator splitting (the paper's complaint
about ``MPI_Comm_Split``): an XOR permutation on bit ``j < dims`` never
leaves the subcube, and grouped collectives use ``axis_index_groups``.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .types import SortShard, local_sort, merge_shards, \
    merge_sorted_shards, pad_value, compact, \
    resize


def xor_perm(p: int, j: int):
    return [(i, i ^ (1 << j)) for i in range(p)]


def subcube_groups(p: int, dims: int):
    """PE groups sharing bits ``dims..`` — the 2^dims-sized subcubes."""
    size = 1 << dims
    return [[h * size + l for l in range(size)] for h in range(p // size)]


def hc_exchange(x, axis_name: str, p: int, j: int):
    """Send ``x`` to partner ``i ^ 2^j``; return the partner's ``x``."""
    return comm.ppermute(x, axis_name, xor_perm(p, j))


def exchange_shard(shard: SortShard, axis_name: str, p: int, j: int) -> SortShard:
    return SortShard(
        keys=hc_exchange(shard.keys, axis_name, p, j),
        vals={k: hc_exchange(v, axis_name, p, j) for k, v in shard.vals.items()},
        count=hc_exchange(shard.count, axis_name, p, j),
    )


# ---------------------------------------------------------------------------
# All-gather-merge (paper §II): all PEs end with all elements, sorted.
# ---------------------------------------------------------------------------


def allgather_merge(shard: SortShard, axis_name: str, p: int,
                    dims: Optional[Sequence[int]] = None,
                    tie_by_origin: bool = True) -> SortShard:
    """Recursive-doubling all-gather-merge over hypercube dims (low→high).

    After step t the buffer holds the merged elements of the 2^(t+1)-subcube.
    When ``tie_by_origin`` is set, equal keys are ordered by origin-PE block
    (lower PE numbers first) — the stable-merge realization of the paper's
    implicit (x, origin, i) lexicographic tie-breaking: at every step the two
    blocks cover disjoint, ordered ranges of origin PEs, so putting the block
    of the lower subcube first on ties yields a global (key, origin, i) order
    without communicating origin ids.
    """
    dims = list(dims) if dims is not None else list(range(p.bit_length() - 1))
    me = comm.axis_index(axis_name)
    for t in dims:
        partner = exchange_shard(shard, axis_name, p, t)
        i_am_upper = ((me >> t) & 1) == 1
        cap = shard.capacity + partner.capacity
        # lower-origin block first on ties: if I am the upper PE, the
        # partner's block is the lower one (traced tie flag).
        tie_a = ~i_am_upper if tie_by_origin else True
        shard, _ = merge_shards(shard, partner, capacity=cap,
                                tie_a_first=tie_a)
    return shard


# ---------------------------------------------------------------------------
# Butterfly reductions (sum / custom) within subcubes.
# ---------------------------------------------------------------------------


def butterfly_sum(x, axis_name: str, p: int, dims: Sequence[int]):
    """All-reduce(+) over the subcube spanned by ``dims``."""
    for t in dims:
        x = jax.tree.map(lambda a, b: a + b, x,
                         hc_exchange(x, axis_name, p, t))
    return x


def subcube_psum(x, axis_name: str, p: int, dims: int):
    """psum within 2^dims subcubes via axis_index_groups (fused collective)."""
    return comm.psum(x, axis_name, axis_index_groups=subcube_groups(p, dims))


def subcube_prefix_sum(x, axis_name: str, p: int, dims: Sequence[int]):
    """Exclusive prefix sum over PE order within the subcube (hypercube scan).

    Classic hypercube scan: maintain (prefix, total); at step t exchange the
    running total with the partner; lower half adds nothing to prefix, upper
    half adds the partner's total.
    """
    me = comm.axis_index(axis_name)
    prefix = jax.tree.map(jnp.zeros_like, x)
    total = x
    for t in dims:
        other_total = jax.tree.map(lambda v: hc_exchange(v, axis_name, p, t), total)
        i_am_upper = ((me >> t) & 1).astype(jnp.int32)
        prefix = jax.tree.map(
            lambda pr, ot: pr + jnp.where(i_am_upper == 1, ot, jnp.zeros_like(ot)),
            prefix, other_total)
        total = jax.tree.map(lambda a, b: a + b, total, other_total)
    return prefix, total


# ---------------------------------------------------------------------------
# Randomized shuffling (paper §III-A / App. C)
# ---------------------------------------------------------------------------


def hypercube_shuffle(shard: SortShard, axis_name: str, p: int, seed,
                      dims: Optional[Sequence[int]] = None
                      ) -> Tuple[SortShard, jax.Array]:
    """Random redistribution in O((α+βn/p)·log p): at each dim, split the
    local data into two random halves and send one to the partner.

    Exactly ⌊m/2⌋ elements are sent each step (the paper's "split local data
    in two random halves" refinement for better load balance).  Returns the
    shuffled shard (unsorted!) and an overflow count.
    """
    dims = list(dims) if dims is not None else list(range(p.bit_length() - 1))
    me = comm.axis_index(axis_name)
    overflow = jnp.int32(0)
    cap = shard.capacity
    for t in dims:
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(seed), t), me)
        scores = jax.random.uniform(key, (cap,))
        scores = jnp.where(shard.valid_mask(), scores, jnp.inf)
        # rank elements by score: the ⌊m/2⌋ smallest are sent.
        order = jnp.argsort(scores)
        rank = jnp.zeros((cap,), jnp.int32).at[order].set(
            jnp.arange(cap, dtype=jnp.int32))
        send_mask = rank < (shard.count // 2)
        sent = compact(shard, send_mask)
        kept = compact(shard, ~send_mask)
        recv = exchange_shard(sent, axis_name, p, t)
        shard, ovf = merge_shards(kept, recv, capacity=cap)
        overflow = overflow + ovf
    return shard, overflow


def alltoall_shuffle(shard: SortShard, axis_name: str, p: int, seed,
                     slot_cap: Optional[int] = None,
                     groups=None, stream: bool = False
                     ) -> Tuple[SortShard, jax.Array]:
    """Direct random shuffle via one fused all-to-all (Helman et al. style).

    On TPU an all-to-all is a single hardware-routed collective, so the αp
    startup penalty the paper associates with direct delivery does not apply;
    volume is βn/p.  Slots are Chernoff-provisioned: targets are uniformly
    random, so per-destination counts concentrate around C/p.

    ``stream=True`` pipelines the exchange against the local merge (see
    :func:`_alltoall_route`): the result is then already locally *sorted*.
    """
    cap = shard.capacity
    if slot_cap is None:
        mean = max(1, cap // p)
        slot_cap = int(mean + 4 * np.sqrt(mean) + 8)
    me = comm.axis_index(axis_name)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), me)
    dest = jax.random.randint(key, (cap,), 0, p).astype(jnp.int32)
    dest = jnp.where(shard.valid_mask(), dest, jnp.int32(p))  # pads → nowhere
    return _alltoall_route(shard, dest, axis_name, p, slot_cap, groups,
                           stream=stream)


def _alltoall_route(shard: SortShard, dest: jax.Array, axis_name: str, p: int,
                    slot_cap: int, groups=None,
                    stream: bool = False) -> Tuple[SortShard, jax.Array]:
    """Scatter elements to ``dest`` PEs via slotted all-to-all buffers.

    ``dest`` is a per-element target in [0, p) (p = group size when grouped);
    invalid elements must carry dest == p.  Returns (shard, overflow); the
    output shard has capacity p*slot_cap and is *unsorted* on the barrier
    path (``stream=False``).

    ``stream=True`` replaces the barrier all_to_all with
    :func:`comm.alltoall_stream`: each arriving per-source block is locally
    sorted and folded into a running merge while later blocks are still in
    flight, so the returned shard is already **sorted** (callers skip their
    ``local_sort``).  Bitwise-identical to the barrier path followed by
    ``local_sort`` — see :func:`_stream_route_merge` for the argument —
    and ``overflow`` is computed sender-side, identically on both paths.
    """
    pad = shard.pad
    # slot index of each element within its destination bucket, via stable
    # sort-by-destination ranking: O(C log C + p) instead of the (C, p)
    # one-hot cumsum, whose p² blow-up (C itself is Θ(p·slot_cap) after a
    # shuffle) was the memory wall at p = 1024 on the sim backend.  The
    # assignment is identical: stable order ⇒ elements keep their original
    # relative order within a destination bucket.
    cap_in = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    first = jnp.searchsorted(sorted_dest, sorted_dest, side="left")
    rank_in_bucket = jnp.arange(cap_in, dtype=jnp.int32) - first.astype(jnp.int32)
    slot = jnp.zeros((cap_in,), jnp.int32).at[order].set(rank_in_bucket)
    bounds = jnp.searchsorted(sorted_dest, jnp.arange(p + 1, dtype=jnp.int32),
                              side="left")
    sent_counts = (bounds[1:] - bounds[:-1]).astype(jnp.int32)    # (p,)
    overflow = jnp.sum(jnp.maximum(sent_counts - slot_cap, 0))
    ok = (dest < p) & (slot < slot_cap)
    flat = dest * slot_cap + slot
    flat = jnp.where(ok, flat, p * slot_cap)  # dump dropped/invalid

    def scatter(v, fill):
        trail = v.shape[1:]
        buf = jnp.full((p * slot_cap + 1,) + trail, fill, v.dtype)
        okb = ok.reshape((-1,) + (1,) * len(trail)) if trail else ok
        buf = buf.at[flat].set(jnp.where(okb, v, fill))
        return buf[:-1].reshape((p, slot_cap) + trail)

    keys = scatter(shard.keys, pad)
    vals = {k: scatter(v, np.zeros((), v.dtype)) for k, v in shard.vals.items()}
    counts = jnp.minimum(sent_counts, slot_cap)                   # (p,)

    if stream:
        out = _stream_route_merge(keys, vals, counts, pad, axis_name, p,
                                  slot_cap, groups)
        return out, overflow

    a2a = lambda v: comm.all_to_all(v, axis_name, split_axis=0, concat_axis=0,
                                    axis_index_groups=groups, tiled=True)
    keys = a2a(keys).reshape(-1)
    vals = {k: a2a(v).reshape((p * slot_cap,) + v.shape[2:])
            for k, v in vals.items()}
    counts = a2a(counts.reshape(p, 1)).reshape(-1)
    out = SortShard(keys=keys, vals=vals, count=jnp.sum(counts).astype(jnp.int32))
    # compact: valid = slot < per-source count
    slot_idx = jnp.arange(p * slot_cap, dtype=jnp.int32) % slot_cap
    valid = slot_idx < jnp.repeat(counts, slot_cap, total_repeat_length=p * slot_cap)
    out = compact(out.replace(count=jnp.int32(p * slot_cap)), valid)
    return out, overflow


def _stream_route_merge(keys, vals, counts, pad, axis_name: str, p: int,
                        slot_cap: int, groups) -> SortShard:
    """Incremental-merge consumer of a streamed slotted exchange.

    Each arriving per-source block is locally sorted *while later blocks
    are still in flight* — that is the work the stream hides behind the
    wire — and staged into a per-source run table at row ``src``.  Once the
    stream drains, the ``p`` sorted runs collapse through a balanced k-way
    merge tree (``log2 p`` levels of :func:`merge_sorted_shards`, lower
    source rank on the left), so the consumer does O(C log p) merge work —
    the same asymptotics as the barrier path's single post-shuffle sort —
    instead of the O(C·p) a naive fold-into-one-accumulator would cost.

    Staging by source rank makes the result invariant to the delivery
    interleaving :func:`comm.alltoall_stream` leaves implementation-defined.
    Ties across sources resolve left-run-first through every tree level,
    i.e. globally ascending (source, slot) — exactly the (stable) order the
    barrier path produces via ``compact`` + a full ``local_sort``, so both
    paths are bitwise-identical.
    """
    cap_out = p * slot_cap

    def empty():
        return {
            "keys": jnp.full((p, slot_cap), pad, keys.dtype),
            "vals": {k: jnp.zeros((p, slot_cap) + v.shape[2:], v.dtype)
                     for k, v in vals.items()},
            "counts": jnp.zeros((p,), jnp.int32)}

    def fold(acc, chunk, src):
        ck = SortShard(
            keys=chunk["keys"].reshape(-1),
            vals={k: v.reshape((slot_cap,) + v.shape[2:])
                  for k, v in chunk["vals"].items()},
            count=chunk["counts"].reshape(()).astype(jnp.int32))
        ck = local_sort(ck)           # overlapped with the in-flight blocks
        src = src.astype(jnp.int32)
        acc = dict(acc)
        acc["keys"] = jax.lax.dynamic_update_slice(
            acc["keys"], ck.keys[None], (src, jnp.int32(0)))
        acc["vals"] = {
            k: jax.lax.dynamic_update_slice(
                acc["vals"][k], v[None],
                (src,) + (jnp.int32(0),) * (v.ndim))
            for k, v in ck.vals.items()}
        acc["counts"] = acc["counts"].at[src].set(ck.count)
        return acc

    x = {"keys": keys, "vals": vals, "counts": counts.reshape(p, 1)}
    st = comm.alltoall_stream(x, axis_name, fold, empty(), p,
                              axis_index_groups=groups)

    def pair_merge(a_keys, a_vals, a_count, b_keys, b_vals, b_count):
        a = SortShard(keys=a_keys, vals=a_vals, count=a_count)
        b = SortShard(keys=b_keys, vals=b_vals, count=b_count)
        merged, _ = merge_sorted_shards(
            a, b, capacity=a.capacity + b.capacity)  # never overflows
        return merged.keys, merged.vals, merged.count

    if p & (p - 1) == 0:
        # power-of-two source count: one vmapped pair-merge per tree level
        rk, rv, rc = st["keys"], st["vals"], st["counts"]
        while rk.shape[0] > 1:
            rk, rv, rc = jax.vmap(pair_merge)(
                rk[0::2], {k: v[0::2] for k, v in rv.items()}, rc[0::2],
                rk[1::2], {k: v[1::2] for k, v in rv.items()}, rc[1::2])
        out = SortShard(keys=rk[0], vals={k: v[0] for k, v in rv.items()},
                        count=rc[0])
    else:
        runs = [SortShard(keys=st["keys"][i],
                          vals={k: v[i] for k, v in st["vals"].items()},
                          count=st["counts"][i])
                for i in range(p)]
        while len(runs) > 1:
            nxt = []
            for i in range(0, len(runs) - 1, 2):
                a, b = runs[i], runs[i + 1]
                merged, _ = merge_sorted_shards(
                    a, b, capacity=a.capacity + b.capacity)
                nxt.append(merged)
            if len(runs) % 2:
                nxt.append(runs[-1])
            runs = nxt
        out = runs[0]
    assert out.capacity == cap_out
    return out


# ---------------------------------------------------------------------------
# Hypercube routing by explicit target PE (paper App. B) — used by RFIS
# delivery and GatherM.  Elements carry their target in vals['_tgt'].
# ---------------------------------------------------------------------------


def route_by_target(shard: SortShard, axis_name: str, p: int,
                    dims: Sequence[int], capacity: Optional[int] = None,
                    sorted_merge: bool = True) -> Tuple[SortShard, jax.Array]:
    """Route each element to PE ``vals['_tgt']`` via per-dim exchanges.

    In iteration j an element moves iff its target differs from the current
    PE in bit j (high→low).  O(α log p) startups; per-step volume is bounded
    by the concentration argument of §V for RFIS delivery.
    """
    me = comm.axis_index(axis_name)
    cap = capacity or shard.capacity
    shard, overflow = resize(shard, cap)
    for j in sorted(dims, reverse=True):
        tgt = shard.vals["_tgt"].astype(jnp.int32)
        move = ((tgt ^ me) >> j) & 1 == 1
        sent = compact(shard, move)
        kept = compact(shard, ~move)
        recv = exchange_shard(sent, axis_name, p, j)
        shard, ovf = merge_shards(kept, recv, capacity=cap)
        overflow = overflow + ovf
    return shard, overflow
