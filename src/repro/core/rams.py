"""RAMS — Robust Multi-level (AMS) Sample Sort (paper §V / App. G).

Per level, within the current subcube of size p_sub (split into k = 2^b
groups):
  1. sample locally *with tie-breakers*: sample composite = (key, pe, pos)
     packed in one u64 — tie-break info is attached to the O(k log k)
     samples only, never to the data elements (the paper's low-overhead
     scheme);
  2. all-gather the samples inside the subcube (grouped collective — the
     TPU analogue of ranking samples with FIS: one fused all-gather beats
     emulating the 2-D grid for tiny arrays, cf. DESIGN.md §2);
  3. select n_b = b·k splitters, classify local elements into n_b buckets
     (Super Scalar Sample Sort classifier with implicit tie-breaking:
     an element's composite is formed *locally* from (key, own_pe, own_pos));
  4. psum the bucket histogram, greedily assign contiguous bucket ranges to
     the k groups (ε-balance: imbalance ≤ max bucket ≈ total/(b·k));
  5. compute each element's target PE inside its group from its *global*
     position (hypercube prefix-scan of histograms) — perfect balance within
     target groups, the property that distinguishes AMS from HykSort;
  6. exchange via one fused all-to-all with Chernoff-provisioned slots.

Static-shape adaptation (DESIGN.md §2): deterministic message assignment
and NBX are replaced by the static SPMD schedule (all-to-all *is* a
deterministic assignment with Θ(k) partners); a one-time random
redistribution at the first level makes the fixed slot capacities sound on
adversarial inputs (same Lemma-1 argument as RQuick — each PE then holds a
random sample of its subcube's data at every level).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .hypercube import (_alltoall_route, alltoall_shuffle, subcube_groups,
                        subcube_prefix_sum)
from .types import SortShard, local_sort, resize
from repro.kernels.partition import partition_buckets

_PE_BITS = 12
_POS_BITS = 20
_HI64 = np.uint64(0xFFFFFFFFFFFFFFFF)


class RAMSResult(NamedTuple):
    shard: SortShard
    overflow: jax.Array


def default_levels(p: int, levels: Optional[int] = None) -> Sequence[int]:
    """Split log2(p) into `levels` groups of bits, high bits first."""
    d = p.bit_length() - 1
    if levels is None:
        levels = 1 if d <= 4 else (2 if d <= 10 else 3)
    levels = max(1, min(levels, d)) if d else 1
    base, rem = divmod(d, levels)
    return [base + (1 if i < rem else 0) for i in range(levels)]


def nested_level_bits(p_outer: int, p_inner: int,
                      levels: Optional[int] = None) -> Sequence[int]:
    """Level schedule aligned to a nested (outer × inner) axis pair.

    The multi-level mapping of arXiv 1410.6754 §4: the **first** level
    splits the data across the 2^b0 = ``p_outer`` slow-axis slices (its
    all_to_all is the only exchange that crosses the outer axis); every
    subsequent level recurses inside one inner-axis subcube, so its
    collectives retarget onto the fast intra axis (see
    ``repro.core.comm.NestedCollectives``).  With ``levels=1`` the single
    level spans both axes (one all-to-all over the whole mesh — the
    samplesort structure).

    >>> nested_level_bits(16, 64)
    [4, 3, 3]
    >>> nested_level_bits(16, 64, levels=2)
    [4, 6]
    >>> nested_level_bits(4, 16, levels=1)
    [6]
    """
    d_o = p_outer.bit_length() - 1
    d_i = p_inner.bit_length() - 1
    assert p_outer.bit_count() == 1 and p_inner.bit_count() == 1
    if d_o == 0:
        return list(default_levels(p_inner, levels))
    if d_i == 0:
        return [d_o]
    if levels == 1:
        return [d_o + d_i]
    inner_levels = None if levels is None else max(1, levels - 1)
    return [d_o] + list(default_levels(p_inner, inner_levels))


def _mix32(x):
    """Bijective 32-bit mix (murmur3 finalizer).

    The tie-break tag only needs to induce *some* total order on duplicates
    (App. G) — but the raw (pe, pos) word orders one PE's duplicates as a
    contiguous run, so on duplicate-heavy inputs an entire source shard
    routes to one destination and overflows its a2a slot (observed at
    p = 64 on Zero).  Mixing keeps the tag injective while decorrelating
    the order from (pe, pos), so duplicates scatter uniformly over buckets
    and the Chernoff slot provisioning applies again.
    """
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _composite(keys_u32, pe, pos, valid):
    tag = _mix32((pe.astype(jnp.uint32) << np.uint32(_POS_BITS))
                 | pos.astype(jnp.uint32))
    c = (keys_u32.astype(jnp.uint64) << np.uint64(_PE_BITS + _POS_BITS)) \
        | tag.astype(jnp.uint64)
    return jnp.where(valid, c, _HI64)


def quantile_splitters(sorted_samples, nb: int, invalid=_HI64):
    """``nb - 1`` evenly spaced order statistics of the valid prefix.

    The shared splitter pick of RAMS, samplesort, and the external lane:
    ``sorted_samples`` is an ascending u64 composite array whose invalid
    entries equal ``invalid`` (and therefore sort to the tail); the i-th
    splitter is the element at rank ``i * n_valid // nb``.  Extracted so
    the three callers stay bitwise-identical.
    """
    n_valid = jnp.sum(sorted_samples != invalid)
    q = (jnp.arange(1, nb, dtype=jnp.int64) * n_valid) // nb
    return sorted_samples[jnp.clip(q, 0, sorted_samples.shape[0] - 1)]


def rams(shard: SortShard, axis_name: str, p: int, *,
         seed: int = 0xA35, levels: Optional[int] = None,
         level_bits: Optional[Sequence[int]] = None,
         oversample: int = 4, tie_break: bool = True,
         shuffle: bool = True, slot_factor: float = 2.0,
         overlap: bool = False) -> RAMSResult:
    """Sort over the whole axis.  Requires uint32 keys (u64 keys would need
    a 128-bit sample composite; psort's key transform covers f32/i32/u32).

    ``level_bits`` overrides the level schedule with an explicit per-level
    bit split (summing to log2 p, high bits first) — on a hierarchical
    mesh the caller aligns the first level to the outer-axis size with
    :func:`nested_level_bits`, which is what confines every later level's
    collectives to the fast intra axis.  The schedule, not the mesh, is
    what the sort depends on: a flat run with the same ``level_bits`` is
    bitwise-identical to the nested run.

    Each phase is traced under a :func:`repro.core.comm.tagged` scope
    (``shuffle``, ``level0``, ``level1``, …), so a counting backend
    attributes per-level launches and bytes.

    ``overlap=True`` streams every slotted exchange (shuffle and levels)
    through :func:`repro.core.comm.alltoall_stream`, folding arriving PE
    blocks into a running merge instead of gathering-then-sorting —
    bitwise-identical output, see ``hypercube._stream_route_merge``.
    """
    if shard.keys.dtype != jnp.uint32:
        raise ValueError("rams requires uint32 keys (use psort's transform)")
    d = p.bit_length() - 1
    assert p.bit_count() == 1 and shard.capacity < (1 << _POS_BITS)
    if level_bits is not None:
        bits = [int(b) for b in level_bits]
        if sum(bits) != d or any(b < 1 for b in bits):
            raise ValueError(f"level_bits {bits} must be >=1 each and sum "
                             f"to log2(p)={d}")
    else:
        bits = default_levels(p, levels)
    cap = shard.capacity
    overflow = jnp.int32(0)
    me = comm.axis_index(axis_name)

    if shuffle:
        with comm.tagged("shuffle"):
            shard, ovf = alltoall_shuffle(
                shard, axis_name, p, seed,
                slot_cap=_slot_cap(cap, p, slot_factor), stream=overlap)
        overflow = overflow + ovf
        if not overlap:                     # streamed arrives sorted
            shard = local_sort(shard)
    else:
        shard = local_sort(shard)
    # drop the shuffle's p·slot_cap slot buffer down to 2× the working
    # capacity — at p = 1024 the inflated buffer (≈112·cap) would otherwise
    # flow through every level's classifier and exchange.  The 2× keeps the
    # provisioning slack the levels' slot caps are scaled from (shrinking
    # all the way to cap tightens _slot_cap enough to overflow on dense
    # uniform inputs).
    shard, ovf = resize(shard, min(shard.capacity, 2 * cap))
    overflow = overflow + ovf

    h = d                                   # dims of the current subcube
    for lvl, b in enumerate(bits):
        with comm.tagged(f"level{lvl}"):
            shard, ovf = _rams_level(shard, axis_name, p, h, b,
                                     seed=seed + 7919 * (lvl + 1),
                                     oversample=oversample,
                                     tie_break=tie_break,
                                     slot_factor=slot_factor,
                                     overlap=overlap)
        overflow = overflow + ovf
        h -= b
    return RAMSResult(shard, overflow)


def _slot_cap(cap: int, p_sub: int, slot_factor: float) -> int:
    mean = max(1.0, cap / p_sub)
    return int(math.ceil(slot_factor * mean + 6 * math.sqrt(mean) + 6))


def _rams_level(shard: SortShard, axis_name: str, p: int, h: int, b: int,
                *, seed, oversample, tie_break, slot_factor,
                overlap: bool = False):
    """One k-way splitting level within the 2^h-subcubes."""
    k = 1 << b
    p_sub = 1 << h
    p_g = p_sub >> b                       # PEs per target group
    # b·k buckets (paper §V): per-level group imbalance is bounded by one
    # bucket ≈ (1 + 1/b)× — with L levels the bounds *compound* to
    # (1 + 1/b)^L, so b = 2 (1.5²≈2.25×) breaks the 2× capacity provision
    # at two levels; b = 4 keeps the product at 1.25²≈1.56×.
    nb = max(k, oversample * k)
    cap = shard.capacity
    me = comm.axis_index(axis_name)
    sub_rel = me & (p_sub - 1)             # my index within the subcube
    groups = subcube_groups(p, h)
    sub_dims = list(range(h))

    # --- 1. local samples with tie-break composites ------------------------
    # sample count scales with the *bucket* count nb (not just k): splitter
    # quantiles must resolve bucket-width mass, else the last level
    # (p_g = 1, where group total == PE load) inherits the full sampling
    # error and breaks the 2× capacity bound (observed at p = 64).
    s_per = max(1, -(-(2 * nb * max(2, int(math.log2(p_sub + 1)))) // p_sub))
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), me), 1)
    pos = jax.random.randint(key, (s_per,), 0, jnp.maximum(shard.count, 1))
    sample_keys = shard.keys[pos]
    valid = (shard.count > 0)
    samp = _composite(sample_keys, jnp.broadcast_to(sub_rel, (s_per,)),
                      pos, valid & (pos < shard.count))
    if not tie_break:
        samp = jnp.where(samp == _HI64, samp,
                         samp & ~np.uint64((1 << (_PE_BITS + _POS_BITS)) - 1))

    # --- 2. gather + sort samples within subcube ---------------------------
    all_samp = comm.all_gather(samp, axis_name, axis_index_groups=groups,
                               tiled=True)
    all_samp = jnp.sort(all_samp)

    # --- 3. select splitters, classify -------------------------------------
    splitters = quantile_splitters(all_samp, nb)                  # (nb-1,)
    # fused SSSS classify + histogram + stable in-bucket rank.  Element
    # composites never materialize as u64: the (key, tag) planes compare
    # lexicographically, which equals the u64 compare since the tag is
    # exactly 32 bits.  Invalid entries (flat index ≥ count — pads sit at
    # the tail of a locally-sorted shard) go to the trash bucket nb.
    elem_pos = jnp.arange(cap, dtype=jnp.int32)
    if tie_break:
        e_ties = _mix32((jnp.broadcast_to(sub_rel, (cap,)).astype(jnp.uint32)
                         << np.uint32(_POS_BITS))
                        | elem_pos.astype(jnp.uint32))
    else:
        e_ties = jnp.zeros((cap,), jnp.uint32)
    s_keys = (splitters >> np.uint64(_PE_BITS + _POS_BITS)).astype(jnp.uint32)
    s_ties = splitters.astype(jnp.uint32)            # low 32 bits
    bucket, q_in_bucket, hist = partition_buckets(
        shard.keys, e_ties, s_keys, s_ties, n_buckets=nb, count=shard.count)

    # --- 4. histogram psum, greedy contiguous group assignment -------------
    hist = hist.astype(jnp.int64)                                   # (nb,)
    my_prefix, totals = subcube_prefix_sum(hist, axis_name, p, sub_dims)
    total = jnp.sum(totals)
    cum = jnp.cumsum(totals)
    cum_before = cum - totals
    mid = cum_before + totals // 2
    g_of_bucket = jnp.clip((mid * k) // jnp.maximum(total, 1), 0, k - 1)
    group_total = jnp.zeros((k,), jnp.int64).at[g_of_bucket].add(totals)
    cum_grp = jnp.cumsum(group_total) - group_total                # before grp

    # --- 5. per-element target PE (perfect balance within groups) ----------
    q_in_bucket = q_in_bucket.astype(jnp.int64)
    bsafe = jnp.clip(bucket, 0, nb - 1)
    g_e = g_of_bucket[bsafe]
    pos_in_group = (cum_before[bsafe] - cum_grp[g_e]
                    + my_prefix[bsafe] + q_in_bucket)
    gt = jnp.maximum(group_total[g_e], 1)
    t_in_group = (pos_in_group * p_g) // gt
    dest = (g_e * p_g + t_in_group).astype(jnp.int32)
    dest = jnp.where(shard.valid_mask(), dest, p_sub)

    # --- 6. fused slotted all-to-all within the subcube --------------------
    out, ovf = _alltoall_route(shard, dest, axis_name, p_sub,
                               _slot_cap(cap, p_sub, slot_factor),
                               groups=groups, stream=overlap)
    if not overlap:                         # streamed arrives sorted
        out = local_sort(out)
    # restore working capacity
    out, ovf2 = resize(out, cap)
    return out, ovf + ovf2
