"""Approximate median selection with a single reduction (paper §III-B).

Each PE forwards the k elements around its local median; internal nodes
merge two windows and keep the middle k.  The paper builds a binary
reduction *tree* (implementable as an MPI reduction op).  On TPU we use the
**butterfly (recursive-doubling)** form instead: at step t, exchange the
window with partner ``i^2^t`` and keep the middle k of the merged 2k.
Merging is multiset-commutative, so both partners compute the *identical*
window; by induction every PE of the subcube ends with the same window —
the splitter is agreed upon without a broadcast (one α·log p term saved vs.
tree + bcast).  Every butterfly output is the value of some balanced binary
combining tree over the p leaf windows, so the estimator distribution
matches the paper's binary tree (App. H: rank error ≈ 1.44·n^(-0.39)).

Windows live in a "lifted" uint64 space: real key u ↦ u+1, with 0 as the
paper's virtual "-inf" filler and 2^64-1 as "+inf" (undefined entries left /
right of a short local sequence).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hypercube import hc_exchange
from .types import SortShard

_LO = np.uint64(0)
_HI = np.uint64(0xFFFFFFFFFFFFFFFF)


def lift(keys_u: jax.Array) -> jax.Array:
    return keys_u.astype(jnp.uint64) + np.uint64(1)


def unlift(w: jax.Array, key_dtype) -> jax.Array:
    return (w - np.uint64(1)).astype(key_dtype)


def local_window(shard: SortShard, k: int, coin: jax.Array) -> jax.Array:
    """k elements around the local median, ±inf-filled (paper's leaf step).

    ``coin`` ∈ {0,1} decides floor/ceil centering for odd counts.
    """
    assert k % 2 == 0, "window size k must be even"
    cap = shard.capacity
    lifted = jnp.where(shard.valid_mask(), lift(shard.keys), _HI)
    ext = jnp.concatenate([
        jnp.full((k,), _LO, jnp.uint64), lifted, jnp.full((k,), _HI, jnp.uint64)])
    m = shard.count
    # window start (0-indexed into `lifted`): m/2 - k/2, +coin when m is odd
    start = m // 2 - k // 2 + jnp.where(m % 2 == 1, coin, 0)
    return jax.lax.dynamic_slice(ext, (start + k,), (k,))


def merge_windows(a: jax.Array, b: jax.Array) -> jax.Array:
    """Middle k of the merged 2k (the internal-node step)."""
    k = a.shape[0]
    merged = jnp.sort(jnp.concatenate([a, b]))
    return jax.lax.dynamic_slice(merged, (k // 2,), (k,))


def local_rank_window(shard: SortShard, k: int, frac: jax.Array) -> jax.Array:
    """k elements around local rank ``floor(frac·(m-1))``, ±inf-filled.

    The quantile generalization of :func:`local_window` (``frac`` ≈ 0.5
    recovers the median window up to the odd-count coin): the leaf step of
    the selection fast path's butterfly, which seeds splitter candidates
    for an arbitrary target rank instead of the median.  ``frac`` may be a
    traced scalar in [0, 1] (one per query when vmapped).
    """
    assert k % 2 == 0, "window size k must be even"
    lifted = jnp.where(shard.valid_mask(), lift(shard.keys), _HI)
    ext = jnp.concatenate([
        jnp.full((k,), _LO, jnp.uint64), lifted, jnp.full((k,), _HI, jnp.uint64)])
    m = shard.count
    r = jnp.floor(frac * jnp.maximum(m - 1, 0).astype(jnp.float64))
    start = r.astype(jnp.int32) - k // 2 + 1
    return jax.lax.dynamic_slice(ext, (start + k,), (k,))


def merge_rank_windows(a: jax.Array, b: jax.Array, frac: jax.Array) -> jax.Array:
    """k-window of the merged 2k centered at rank fraction ``frac``.

    ``frac = 0.5`` keeps the middle k — exactly :func:`merge_windows`; other
    fractions slide the kept window toward the target quantile so the
    butterfly tracks an arbitrary order statistic's neighborhood.
    """
    k = a.shape[0]
    merged = jnp.sort(jnp.concatenate([a, b]))
    start = jnp.clip(jnp.round(frac * (2 * k)).astype(jnp.int32) - k // 2,
                     0, k)
    return jax.lax.dynamic_slice(merged, (start,), (k,))


def butterfly_rank_window(shard: SortShard, axis_name: str, p: int,
                          dims: Sequence[int], k: int,
                          fracs: jax.Array) -> jax.Array:
    """Per-query rank windows, agreed across the subcube (lifted space).

    ``fracs`` is a (B,) batch of target rank fractions; returns (B, k)
    windows.  Same induction as :func:`butterfly_median_window`: merging is
    multiset-commutative and both partners keep the same slice, so every PE
    of the subcube ends with identical windows — the selection fast path
    uses their entries as round-0 splitter candidates without a broadcast.
    """
    w = jax.vmap(lambda f: local_rank_window(shard, k, f))(fracs)   # (B, k)
    for t in dims:
        wp = hc_exchange(w, axis_name, p, t)
        w = jax.vmap(merge_rank_windows)(w, wp, fracs)
    return w


def butterfly_median_window(shard: SortShard, axis_name: str, p: int,
                            dims: Sequence[int], k: int,
                            seed) -> jax.Array:
    """All PEs of the subcube spanned by ``dims`` obtain the same k-window."""
    # deterministic coin shared by the whole subcube (seed has no PE term)
    key = jax.random.PRNGKey(seed)
    coin = jax.random.bernoulli(key).astype(jnp.int32)
    w = local_window(shard, k, coin)
    for t in dims:
        w = merge_windows(w, hc_exchange(w, axis_name, p, t))
    return w


def splitter_from_window(w: jax.Array, seed) -> Tuple[jax.Array, jax.Array]:
    """Pick the window median (a[k/2] vs a[k/2+1] by coin), still lifted.

    Returns (splitter_lifted, is_empty).  A window that is entirely ±inf
    filler means the subcube holds no elements.
    """
    k = w.shape[0]
    coin = jax.random.bernoulli(jax.random.fold_in(
        jax.random.PRNGKey(seed), 1)).astype(jnp.int32)
    s = w[k // 2 - 1 + coin]
    # fall back to the other candidate if the coin picked a filler
    other = w[k // 2 - coin]
    s = jnp.where((s == _LO) | (s == _HI), other, s)
    is_empty = (s == _LO) | (s == _HI)
    return s, is_empty
