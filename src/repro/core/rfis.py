"""Robust Fast Work-Inefficient Sorting (paper §V, App. D1/F).

PEs form a conceptual √p × √p grid over the sort axis: column index = low
``cb`` bits, row index = high ``rb`` bits.  Steps:

  1. local sort;
  2. all-gather-merge within rows and within columns (hypercube doubling,
     O(α log p + β n/√p));
  3. every PE ranks its row's elements within its column's elements under
     the total order (key, origin_row, origin_col, local_idx) — the paper's
     quadruple tie-breaking.  The gathered sequences arrive *already* in
     that lexicographic order because every doubling step merges two blocks
     with disjoint, ordered origin ranges ("left block first on ties" — the
     SPMD realization of the paper's ←/H/→ bucket trick);
  4. allreduce(+) of the partial ranks across the row ⇒ each PE knows the
     global rank of every element of its row.  A *column* of PEs therefore
     stores the complete ranked input;
  5. delivery: element with rank g targets PE g·p/n; each element is kept
     by exactly one column and routed within it (hypercube routing over the
     row dims).  Output is perfectly balanced (⌈n/p⌉).

SPMD adaptation note (DESIGN.md §2): the paper communicates *zero* origin
information by keeping three physical buckets per PE; static shapes force
us to carry two u32 side arrays (origin PE, local index) through the
gathers instead.  The mechanism — lexicographic quadruple tie-breaking
computed from merge provenance, no global id materialization before the
gather — is preserved.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .hypercube import allgather_merge, butterfly_sum, route_by_target
from .types import SortShard, compact, local_sort

_U32 = np.uint64(0xFFFFFFFF)


class RFISResult(NamedTuple):
    shard: SortShard
    overflow: jax.Array


class RFISRanks(NamedTuple):
    """Ranking-only output: my row's gathered elements + their global ranks."""
    row_data: SortShard
    ranks: jax.Array           # (|row_data|,) int64, valid where row mask
    total: jax.Array           # () global element count


def grid_shape(p: int):
    d = p.bit_length() - 1
    cb = d // 2               # column bits (low) — row size 2^cb
    rb = d - cb               # row bits (high)  — column size 2^rb
    return rb, cb


def _with_origin(shard: SortShard, axis_name: str) -> SortShard:
    me = comm.axis_index(axis_name).astype(jnp.uint32)
    cap = shard.capacity
    vals = dict(shard.vals)
    vals["_orig"] = jnp.full((cap,), me, jnp.uint32)
    vals["_lidx"] = jnp.arange(cap, dtype=jnp.uint32)
    return shard.replace(vals=vals)


def rfis_rank(shard: SortShard, axis_name: str, p: int) -> RFISRanks:
    """Compute global ranks of all elements in my row (steps 1–4)."""
    rb, cb = grid_shape(p)
    me = comm.axis_index(axis_name)
    my_row = me >> cb
    my_col = me & ((1 << cb) - 1)

    shard = _with_origin(local_sort(shard), axis_name)
    row = allgather_merge(shard, axis_name, p, dims=range(cb))
    col = allgather_merge(shard, axis_name, p, dims=range(cb, cb + rb))

    # --- partial rank of each row element within my column's data ---------
    # row element a = (y, r=my_row, C_a, i);  col element b = (x, R_b, c=my_col, j)
    # contribution = #{b : (x, R_b, c, j) < (y, my_row, C_a, i)}
    y = row.keys                                   # (Nr,)
    Ca = (row.vals["_orig"].astype(jnp.int64)) & ((1 << cb) - 1)
    i_idx = row.vals["_lidx"].astype(jnp.int64)
    x = col.keys                                   # (Nc,)
    Rb = (col.vals["_orig"].astype(jnp.int64)) >> cb
    j_idx = col.vals["_lidx"].astype(jnp.int64)
    col_valid = col.valid_mask()

    base = jnp.searchsorted(jnp.where(col_valid, x, col.pad), y,
                            side="left").astype(jnp.int64)
    # equal-key refinement via origin subkeys (2-D compare; RFIS operates in
    # the sparse regime where gathered sizes are O(n/√p), cf. docstring)
    scu = (Rb << 32) | j_idx                       # col subkey (R_b, j)
    # threshold per row element:  C_a > c ⇒ (my_row+1)<<32 ;  C_a < c ⇒ my_row<<32
    #                             C_a == c ⇒ my_row<<32 | i
    mr = jnp.int64(my_row)
    thr = jnp.where(Ca > my_col, (mr + 1) << 32,
                    jnp.where(Ca < my_col, mr << 32, (mr << 32) | i_idx))
    eq = (x[None, :] == y[:, None]) & col_valid[None, :]
    tie_cnt = jnp.sum(eq & (scu[None, :] < thr[:, None]), axis=1)
    partial = jnp.where(row.valid_mask(), base + tie_cnt, 0)

    ranks = butterfly_sum(partial, axis_name, p, dims=range(cb))
    total = butterfly_sum(col.count.astype(jnp.int64), axis_name, p,
                          dims=range(cb))
    return RFISRanks(row_data=row, ranks=ranks, total=total)


def rfis(shard: SortShard, axis_name: str, p: int, *,
         capacity: Optional[int] = None) -> RFISResult:
    """Full RFIS: rank + balanced delivery (step 5)."""
    rb, cb = grid_shape(p)
    me = comm.axis_index(axis_name)
    my_col = me & ((1 << cb) - 1)
    out_cap = capacity or shard.capacity

    rk = rfis_rank(shard, axis_name, p)
    row, ranks, total = rk.row_data, rk.ranks, rk.total
    out_per = jnp.maximum((total + p - 1) // p, 1)
    target = (ranks // out_per).astype(jnp.int32)

    keep = row.valid_mask() & ((target & ((1 << cb) - 1)) == my_col)
    vals = dict(row.vals)
    vals["_tgt"] = target.astype(jnp.uint32)
    row = row.replace(vals=vals)
    kept = compact(row, keep)
    # route within my column (row dims); capacity = whole-column volume is a
    # hard upper bound on any intermediate load
    route_cap = max(out_cap, kept.capacity)
    routed, overflow = route_by_target(kept, axis_name, p,
                                       dims=range(cb, cb + rb),
                                       capacity=route_cap)
    routed = local_sort(routed)
    # shrink to output capacity
    from .types import resize
    out, ovf2 = resize(routed, out_cap)
    out = out.replace(vals={k: v for k, v in out.vals.items()
                            if not k.startswith("_")})
    return RFISResult(out, overflow + ovf2)
