"""Public API: ``psort`` — distributed sort over a mesh axis.

This is the paper's headline deliverable as a library: one entry point that
covers the entire n/p spectrum by dispatching to GatherM / RFIS / RQuick /
RAMS (``algorithm="auto"``, §IV Table I thresholds re-derived for TPU v5e in
``selection.py``), with robust behavior on all input distributions.

Two layers:
  * ``*_inner`` functions (imported from the algorithm modules) run inside
    ``shard_map`` and compose with other shard_map code (e.g. MoE dispatch);
  * ``psort`` is the host-level convenience wrapper: takes a global array,
    builds the mesh + shard_map, returns the globally sorted array.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import selection
from .types import SortShard, key_to_uint, make_shard, pad_value, uint_to_key

try:  # jax >= 0.4.35 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def default_mesh(p: Optional[int] = None, axis: str = "sort") -> Mesh:
    devs = jax.devices()
    p = p or len(devs)
    if p > len(devs):
        raise ValueError(f"requested p={p} > available devices {len(devs)}")
    return Mesh(np.array(devs[:p]), (axis,))


def _algorithm_fn(name: str):
    # lazy per-name imports to avoid cycles and partial-build breakage
    if name in ("rquick", "ntb-quick"):
        from .rquick import rquick
        fn = rquick if name == "rquick" else partial(rquick, robust=False)
    elif name == "rfis":
        from .rfis import rfis as fn
    elif name in ("rams", "ntb-ams"):
        from .rams import rams
        fn = rams if name == "rams" else partial(rams, tie_break=False)
    elif name == "bitonic":
        from .bitonic import bitonic as fn
    elif name in ("ssort", "ns-ssort"):
        from .samplesort import samplesort
        fn = samplesort if name == "ssort" else partial(samplesort, robust=False)
    elif name == "gatherm":
        from .gatherm import gather_merge as fn
    elif name == "allgatherm":
        from .gatherm import allgather_merge_sort as fn
    else:
        raise ValueError(f"unknown algorithm {name!r}")
    return _wrap_result(fn)


def _wrap_result(fn):
    def wrapped(shard, axis_name, p, **kw):
        out = fn(shard, axis_name, p, **kw)
        if isinstance(out, tuple) and not hasattr(out, "shard"):
            return out
        return out.shard, out.overflow
    return wrapped


@partial(jax.jit, static_argnames=("algorithm", "axis_name", "p", "capacity",
                                   "out_capacity", "mesh", "algo_kw"))
def _psort_jit(keys2d, counts, mesh, axis_name, p, algorithm, capacity,
               out_capacity, algo_kw):
    algo_kw = dict(algo_kw)

    def body(keys_blk, count_blk):
        per = keys_blk.shape[1]
        # global index payload proves permutation-ness in tests
        base = jax.lax.axis_index(axis_name).astype(jnp.uint32) * np.uint32(per)
        idx = base + jnp.arange(per, dtype=jnp.uint32)
        shard = make_shard(keys_blk[0], count=count_blk[0], capacity=capacity,
                           vals={"idx": idx})
        fn = _algorithm_fn(algorithm)
        out, overflow = fn(shard, axis_name, p, **algo_kw)
        overflow = overflow + jnp.maximum(out.count - out_capacity, 0)
        ok = jnp.minimum(out.count, out_capacity)
        keys = out.keys[:out_capacity]
        idx = out.vals.get("idx", jnp.zeros((out.capacity,), jnp.uint32))[:out_capacity]
        return keys[None], idx[None], ok[None], overflow[None]

    out = shard_map(body, mesh=mesh,
                    in_specs=(P(axis_name), P(axis_name)),
                    out_specs=(P(axis_name),) * 4,
                    check_vma=False)(keys2d, counts)
    return out


def psort(keys, p: Optional[int] = None, algorithm: str = "auto",
          mesh: Optional[Mesh] = None, axis: str = "sort",
          capacity_factor: float = 2.0, return_info: bool = False,
          **algo_kw):
    """Sort a host array with p emulated PEs.  Returns the sorted array
    (and an info dict with overflow / balance when ``return_info``)."""
    mesh = mesh or default_mesh(p, axis)
    p = mesh.shape[axis]
    keys = jnp.asarray(keys)
    n = keys.shape[0]
    orig_dtype = keys.dtype
    u = key_to_uint(keys)

    per = -(-max(n, 1) // p)                       # ceil(n/p)
    capacity = max(4, int(np.ceil(per * capacity_factor)))
    if algorithm == "auto":
        algorithm = selection.select_algorithm(n, p)
    out_capacity = _out_capacity(algorithm, n, p, per, capacity)

    pad = pad_value(u.dtype)
    flat = jnp.full((p * per,), pad, u.dtype).at[:n].set(u)
    keys2d = flat.reshape(p, per)
    counts = jnp.minimum(jnp.maximum(n - per * jnp.arange(p), 0), per).astype(jnp.int32)

    keys_out, idx_out, counts_out, overflow = _psort_jit(
        keys2d, counts, mesh, axis, p, algorithm, capacity, out_capacity,
        tuple(sorted(algo_kw.items())))
    keys_out = np.asarray(keys_out)
    counts_out = np.asarray(counts_out)
    pe_range = range(1) if algorithm == "allgatherm" else range(p)
    parts = [keys_out[i, :counts_out[i]] for i in pe_range]
    result = uint_to_key(jnp.asarray(np.concatenate(parts)), orig_dtype)
    if return_info:
        idx_parts = [np.asarray(idx_out)[i, :counts_out[i]] for i in range(p)]
        info = {
            "algorithm": algorithm,
            "counts": counts_out,
            "overflow": int(np.asarray(overflow).sum()),
            "balance": counts_out.max() / max(1.0, n / p),
            "perm": np.concatenate(idx_parts) if n else np.zeros((0,), np.uint32),
            "n": n,
        }
        return result, info
    return result


def _out_capacity(algorithm: str, n: int, p: int, per: int, capacity: int) -> int:
    if algorithm in ("gatherm", "allgatherm"):
        return max(1, p * per)                     # concentrated output
    return capacity
