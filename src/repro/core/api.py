"""Public API: ``psort`` — distributed sort over a mesh axis.

This is the paper's headline deliverable as a library: one entry point that
covers the entire n/p spectrum by dispatching to GatherM / RFIS / RQuick /
RAMS (``algorithm="auto"``, §IV Table I thresholds re-derived for TPU v5e in
``selection.py``), with robust behavior on all input distributions.

Two layers:
  * ``*_inner`` functions (imported from the algorithm modules) run inside
    ``shard_map`` and compose with other shard_map code (e.g. MoE dispatch);
  * ``psort`` is the host-level convenience wrapper: takes a global array,
    builds the mesh + shard_map, returns the globally sorted array.

Execution backends (``backend=``):
  * ``"shard_map"`` — one shard per device over a mesh axis (production; p
    is capped by the available device count);
  * ``"sim"`` — single-process simulation: the same per-PE body is vmapped
    over a leading PE axis with collectives routed through
    ``repro.core.comm``, lifting the device cap (p = 64–1024 emulated PEs).
Both backends trace the identical body with identical PRNG folding, so
their outputs match bit for bit at equal (n, p, algorithm, seed).

Multi-axis meshes: a 2-D ``keys`` array of shape (d, n) is a batch of d
independent sort problems laid out over a (``data_axis``, ``axis``) mesh —
each row is sorted within its own p-sized sort-axis subgroup and the data
axis never communicates.  Because every collective resolves relative to
the named sort axis (see ``repro.core.comm.Collectives``), row r of the
batched output is bit-identical to a 1-D ``psort`` of row r at the same
(n, p, algorithm, seed).  On ``backend="shard_map"`` the mesh is a real
2-D device mesh (``repro.dist.sharding.sort_mesh``); on ``backend="sim"``
it is emulated via ``comm.sim_map(..., mesh=(d, p))``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.compat import shard_map

from . import comm, selection
from .types import (SortShard, key_to_uint, local_kernels, make_shard,
                    pad_value, uint_to_key)

BACKENDS = ("shard_map", "sim")

# algorithms with a slotted exchange the streamed pipeline can overlap; the
# rest (ppermute/all_gather structures) have nothing to stream and run the
# barrier path under overlap=True unchanged (trivially bitwise-equal)
_OVERLAP_ALGOS = ("rams", "ntb-ams", "ssort", "ns-ssort")


@dataclasses.dataclass(frozen=True)
class SortConfig:
    """Everything that shapes one distributed sort, in one hashable object.

    ``psort(keys, config=SortConfig(...))`` is the primary call style; the
    jit caches key on the whole config, so two calls with equal configs hit
    the same executable.  Fields group into:

    **Topology** — ``p`` (PE count; read off ``mesh``/``mesh_shape`` when
    omitted on shard_map), ``mesh`` (explicit device mesh, shard_map only;
    excluded from equality/hash — pass the same mesh object to reuse the
    cache), ``axis``/``data_axis`` (mesh axis names), ``mesh_shape`` +
    ``mesh_axes`` (hierarchical nested-axis runs), ``levels`` (AMS level
    count).

    **Execution** — ``backend`` (``"shard_map"`` | ``"sim"``),
    ``algorithm`` (``"auto"`` consults the cost model), ``cost_model``
    (:class:`repro.core.selection.CostModel` machine profile),
    ``capacity_factor`` (slack of the per-PE shard buffers).

    **Resilience / streaming** — ``fault_policy``
    (:class:`repro.runtime.failures.FaultPolicy`; mutable, excluded from
    equality/hash), ``external``
    (:class:`repro.core.external.ExternalPolicy` out-of-core streaming),
    and ``overlap`` (pipeline every slotted exchange against the local
    merge via ``comm.alltoall_stream`` — bitwise-identical output; a no-op
    for algorithms without a slotted all_to_all).

    ``algo_kw`` holds algorithm-specific keywords (``slot_factor``,
    ``oracle_splitters``, ``tie_break``, …) as a sorted tuple of pairs —
    :meth:`from_kwargs` splits a flat kwarg dict into fields and
    ``algo_kw``, which is also what the legacy-kwarg shim uses.

    See the README migration table for the legacy-kwarg ↔ field mapping.
    """

    # topology
    p: Optional[int] = None
    mesh: Optional[Mesh] = dataclasses.field(default=None, compare=False)
    axis: str = "sort"
    data_axis: str = "data"
    mesh_shape: Optional[tuple] = None
    mesh_axes: tuple = ("inter", "intra")
    levels: Optional[int] = None
    # execution
    backend: str = "shard_map"
    algorithm: str = "auto"
    cost_model: Optional[selection.CostModel] = None
    capacity_factor: float = 2.0
    # resilience / streaming
    fault_policy: Optional[object] = dataclasses.field(default=None,
                                                       compare=False)
    external: Optional[object] = None
    overlap: bool = False
    # algorithm-specific keywords, normalized to a sorted tuple of pairs
    algo_kw: tuple = ()

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected "
                             f"{BACKENDS}")
        if self.mesh_shape is not None:
            object.__setattr__(self, "mesh_shape",
                               tuple(int(v) for v in self.mesh_shape))
        object.__setattr__(self, "mesh_axes", tuple(self.mesh_axes))
        kw = dict(self.algo_kw) if not isinstance(self.algo_kw, dict) \
            else self.algo_kw
        norm = {k: tuple(v) if isinstance(v, list) else v
                for k, v in kw.items()}
        object.__setattr__(self, "algo_kw", tuple(sorted(norm.items())))

    @classmethod
    def from_kwargs(cls, **kw) -> "SortConfig":
        """Split a flat legacy-style kwarg dict into config fields plus
        ``algo_kw`` (anything that is not a field)."""
        cfg = {k: kw.pop(k) for k in list(kw) if k in _CONFIG_FIELDS}
        return cls(algo_kw=kw, **cfg)

    def replace(self, **changes) -> "SortConfig":
        return dataclasses.replace(self, **changes)


_CONFIG_FIELDS = frozenset(
    f.name for f in dataclasses.fields(SortConfig)) - {"algo_kw"}


def _coerce_config(config, legacy: dict, caller: str) -> SortConfig:
    """Resolve the (config | legacy kwargs) call styles to one SortConfig.

    Exactly one :class:`DeprecationWarning` per legacy-style call; mixing
    the styles is a :class:`TypeError`.  A bare int ``config`` is the old
    positional ``p``.
    """
    if isinstance(config, (int, np.integer)):      # legacy positional p
        legacy = {"p": int(config), **legacy}
        config = None
    if config is not None:
        if legacy:
            raise TypeError(
                f"{caller}() got both config= and legacy keyword arguments "
                f"{sorted(legacy)}; move them into the SortConfig")
        if not isinstance(config, SortConfig):
            raise TypeError(f"{caller}() config must be a SortConfig, got "
                            f"{type(config).__name__}")
        return config
    if not legacy:
        return SortConfig()
    warnings.warn(
        f"{caller}(keys, p=..., algorithm=..., ...) keyword style is "
        f"deprecated; pass {caller}(..., config=SortConfig(...)) instead "
        f"(field mapping: README 'Migrating to SortConfig')",
        DeprecationWarning, stacklevel=3)
    return SortConfig.from_kwargs(**legacy)


def default_mesh(p: Optional[int] = None, axis: str = "sort") -> Mesh:
    devs = jax.devices()
    p = p or len(devs)
    if p > len(devs):
        raise ValueError(f"requested p={p} > available devices {len(devs)}"
                         f" (use backend='sim' for emulated PE counts)")
    return Mesh(np.array(devs[:p]), (axis,))


def _algorithm_fn(name: str):
    # lazy per-name imports to avoid cycles and partial-build breakage
    if name in ("rquick", "ntb-quick"):
        from .rquick import rquick
        fn = rquick if name == "rquick" else partial(rquick, robust=False)
    elif name == "rfis":
        from .rfis import rfis as fn
    elif name in ("rams", "ntb-ams"):
        from .rams import rams
        fn = rams if name == "rams" else partial(rams, tie_break=False)
    elif name == "bitonic":
        from .bitonic import bitonic as fn
    elif name in ("ssort", "ns-ssort"):
        from .samplesort import samplesort
        fn = samplesort if name == "ssort" else partial(samplesort, robust=False)
    elif name == "gatherm":
        from .gatherm import gather_merge as fn
    elif name == "allgatherm":
        from .gatherm import allgather_merge_sort as fn
    else:
        raise ValueError(f"unknown algorithm {name!r}")
    return _wrap_result(fn)


def _wrap_result(fn):
    def wrapped(shard, axis_name, p, **kw):
        out = fn(shard, axis_name, p, **kw)
        if isinstance(out, tuple) and not hasattr(out, "shard"):
            return out
        return out.shard, out.overflow
    return wrapped


def _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw):
    """The per-PE SPMD body shared by both backends.

    Takes (keys (per,), count ()) for one PE, returns (keys (out_cap,),
    idx (out_cap,), count (), overflow ()).
    """
    algo_kw = dict(algo_kw)

    def body(keys_pe, count_pe):
        per = keys_pe.shape[0]
        # global index payload proves permutation-ness in tests
        base = comm.axis_index(axis_name).astype(jnp.uint32) * np.uint32(per)
        idx = base + jnp.arange(per, dtype=jnp.uint32)
        shard = make_shard(keys_pe, count=count_pe, capacity=capacity,
                           vals={"idx": idx})
        fn = _algorithm_fn(algorithm)
        out, overflow = fn(shard, axis_name, p, **algo_kw)
        overflow = overflow + jnp.maximum(out.count - out_capacity, 0)
        ok = jnp.minimum(out.count, out_capacity)
        keys = out.keys[:out_capacity]
        idx = out.vals.get("idx", jnp.zeros((out.capacity,), jnp.uint32))[:out_capacity]
        return keys, idx, ok, overflow

    return body


@partial(jax.jit, static_argnames=("cfg", "algorithm", "axis_name", "p",
                                   "capacity", "out_capacity", "mesh",
                                   "algo_kw", "pallas"))
def _psort_jit(keys2d, counts, mesh, cfg, axis_name, p, algorithm, capacity,
               out_capacity, algo_kw, pallas):
    body = _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw)

    def blk(keys_blk, count_blk):
        k, i, c, o = body(keys_blk[0], count_blk[0])
        return k[None], i[None], c[None], o[None]

    out = shard_map(blk, mesh=mesh,
                    in_specs=(P(axis_name), P(axis_name)),
                    out_specs=(P(axis_name),) * 4)(keys2d, counts)
    return out


@partial(jax.jit, static_argnames=("cfg", "algorithm", "axis_name", "p",
                                   "capacity", "out_capacity", "algo_kw",
                                   "pallas"))
def _psort_sim_jit(keys2d, counts, cfg, axis_name, p, algorithm, capacity,
                   out_capacity, algo_kw, pallas):
    body = _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw)
    return comm.sim_map(body, axis_name, p)(keys2d, counts)


@partial(jax.jit, static_argnames=("cfg", "algorithm", "axis_name",
                                   "data_axis", "p", "capacity",
                                   "out_capacity", "mesh", "algo_kw",
                                   "pallas"))
def _psort2_jit(keys3d, counts, mesh, cfg, axis_name, data_axis, p, algorithm,
                capacity, out_capacity, algo_kw, pallas):
    """Batched psort over the sort axis of a 2-D (data, sort) device mesh."""
    body = _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw)

    def blk(keys_blk, count_blk):          # (1, 1, per), (1, 1)
        k, i, c, o = body(keys_blk[0, 0], count_blk[0, 0])
        return (k[None, None], i[None, None], c[None, None], o[None, None])

    out = shard_map(blk, mesh=mesh,
                    in_specs=(P(data_axis, axis_name),
                              P(data_axis, axis_name)),
                    out_specs=(P(data_axis, axis_name),) * 4)(keys3d, counts)
    return out


@partial(jax.jit, static_argnames=("cfg", "algorithm", "axis_name",
                                   "data_axis", "d", "p", "capacity",
                                   "out_capacity", "algo_kw", "pallas"))
def _psort2_sim_jit(keys3d, counts, cfg, axis_name, data_axis, d, p,
                    algorithm, capacity, out_capacity, algo_kw, pallas):
    body = _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw)
    return comm.sim_map(body, axis_name, p, mesh=(d, p),
                        data_axis=data_axis)(keys3d, counts)


@partial(jax.jit, static_argnames=("cfg", "algorithm", "axis_name",
                                   "data_axis", "axes", "p", "capacity",
                                   "out_capacity", "mesh", "algo_kw",
                                   "pallas"))
def _psort_nested_jit(keys_nd, counts, mesh, cfg, axis_name, data_axis, axes,
                      p, algorithm, capacity, out_capacity, algo_kw, pallas):
    """psort over the virtual flat axis of a nested (inter, intra) mesh.

    The body is the *same* per-PE body as the flat path; its collectives
    name ``axis_name`` and the :func:`repro.core.comm.nested` scope
    decomposes them onto the real mesh axes while tracing.  ``data_axis``
    (when not None) leads for batched keys.
    """
    body = _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw)
    names = ((data_axis,) if data_axis else ()) + tuple(n for n, _ in axes)
    nlead = len(names)

    def blk(keys_blk, count_blk):
        with comm.nested(axis_name, axes):
            k, i, c, o = body(keys_blk.reshape(keys_blk.shape[nlead:]),
                              count_blk.reshape(()))
        dims = tuple(range(nlead))
        return tuple(jnp.expand_dims(v, dims) for v in (k, i, c, o))

    out = shard_map(blk, mesh=mesh,
                    in_specs=(P(*names), P(*names)),
                    out_specs=(P(*names),) * 4)(keys_nd, counts)
    return out


@partial(jax.jit, static_argnames=("cfg", "algorithm", "axis_name",
                                   "data_axis", "d", "axes", "p", "capacity",
                                   "out_capacity", "algo_kw", "pallas"))
def _psort_nested_sim_jit(keys_nd, counts, cfg, axis_name, data_axis, d, axes,
                          p, algorithm, capacity, out_capacity, algo_kw,
                          pallas):
    body = _sort_body(axis_name, p, algorithm, capacity, out_capacity, algo_kw)
    return comm.sim_map(body, axis_name, p, nested=axes,
                        mesh=(d, p) if data_axis else None,
                        data_axis=data_axis)(keys_nd, counts)


def psort(keys, config=None, *, return_info: bool = False, **legacy):
    """Sort a host array over the ``axis`` mesh axis with p (emulated) PEs.

    ``config`` is a :class:`SortConfig` carrying every knob — topology,
    execution, resilience/streaming and algorithm keywords.  The legacy
    flat-kwarg style (``psort(x, p=4, algorithm="rquick", ...)``) still
    works through a shim that builds the equivalent config and emits one
    :class:`DeprecationWarning` per call; a bare int second argument is
    the old positional ``p``.  Mixing ``config=`` with legacy kwargs is a
    :class:`TypeError`.  See the README's "Migrating to SortConfig" table.

    Returns the sorted array (and an info dict with overflow / balance when
    ``return_info``).  1-D ``keys`` of shape (n,) are one global sort
    problem; 2-D ``keys`` of shape (d, n) are d **independent** problems
    laid out over a (``data_axis``, ``axis``) mesh — each row is sorted
    within its own sort-axis subgroup, bit-identical to d separate 1-D
    calls (the multi-axis-mesh contract, see ``docs/ARCHITECTURE.md``).

    ``mesh`` (``backend="shard_map"`` only) supplies the device mesh: 1-D
    over ``axis`` for 1-D keys, 2-D over (``data_axis``, ``axis``) for 2-D
    keys (default: ``repro.dist.sharding.sort_mesh``).  ``backend="sim"``
    runs meshless and needs an explicit ``p``; the data-axis extent is
    read off ``keys.shape[0]``.

    **Hierarchical meshes** — ``mesh_shape=(p_outer, p_inner)`` sorts over
    the *nested* axis pair ``mesh_axes`` (default ``("inter", "intra")``)
    of a hierarchical mesh instead of one flat axis: the algorithms still
    see a single virtual axis of size ``p_outer·p_inner``, but every
    collective is decomposed onto the real axes
    (``repro.core.comm.NestedCollectives``), and RAMS aligns its level
    schedule to the axis boundary (``repro.core.rams.nested_level_bits``)
    so the first level's all_to_all is the **only** exchange crossing the
    slow outer axis — every later level recurses inside an intra subcube.
    Bitwise-identical to the flat run of the same schedule.  On
    ``backend="shard_map"`` the mesh is ``sort_mesh(shape=mesh_shape)``;
    on ``backend="sim"`` the hierarchy is emulated (``p`` may be omitted).

    ``levels`` (multi-level AMS family only) picks the number of RAMS
    levels: flat it forwards to ``rams(levels=...)``; nested, the first
    level is pinned to the outer axis and ``levels - 1`` levels split the
    inner axis.  ``levels=1`` is the single-exchange samplesort structure.

    ``cost_model`` parameterizes ``algorithm="auto"``: a
    :class:`repro.core.selection.CostModel` machine profile (e.g. loaded
    from a ``profiles/<machine>.json`` written by
    ``benchmarks/calibrate.py``); defaults to the prior profile.

    **Fault tolerance** — ``fault_policy`` (a
    :class:`repro.runtime.failures.FaultPolicy`, sim backend only) runs
    the sort under its :class:`repro.core.comm.FaultPlan`: each attempt
    is freshly traced under a :class:`repro.core.comm.FaultyCollectives`
    decorator, a fired kill (:class:`repro.core.comm.PEFailure`) or a
    watchdog-flagged straggler excludes the PE, the topology is re-planned
    (``repro.runtime.elastic.plan_sort_rescale`` — survivors rounded down
    to a power of two, nested inner axis preserved while it fits), the
    input is redistributed over the new mesh and the sort re-runs —
    ``algorithm="auto"`` re-consults ``select_algorithm`` at the reduced
    p.  Retries are bounded by ``policy.max_restarts`` via
    ``repro.runtime.failures.run_with_restarts``.  Afterwards
    ``policy.trace`` holds the merged :class:`repro.core.comm.CommTrace`
    (injected ``fault:*`` events, ``rescale`` markers, regular launches)
    and ``policy.attempts`` one record per attempt; with ``return_info``
    the info dict gains ``"fault"`` and ``"comm_trace"`` entries.  See
    ``docs/ARCHITECTURE.md`` ("Fault tolerance").

    **External memory** — ``external`` (a
    :class:`repro.core.external.ExternalPolicy`, sim backend, 1-D flat
    axis only) lifts the device-memory cap on n/p: shards larger than
    ``external.budget`` elements live in host memory and stream through
    the device in run-formation / splitter-fit / per-run-exchange /
    k-way-merge passes (see ``repro/core/external.py``).  The output is
    bitwise-equal to the in-core path — it is *the* globally sorted
    array.  ``algorithm="auto"`` consults the cost model's external
    regime (``select_algorithm(..., budget=...)``); shards that fit the
    budget run the normal in-core path.  The ``REPRO_EXTERNAL_BUDGET``
    environment variable applies a default policy when ``external`` is
    omitted.  Composes with ``fault_policy``: a kill during any external
    pass excludes the PE and re-runs the whole multi-pass pipeline at the
    reduced topology.

    >>> import numpy as np
    >>> from repro.core.api import SortConfig, psort
    >>> x = np.array([5, 3, 1, 4, 2, 9, 8, 6], np.int32)
    >>> cfg = SortConfig(p=4, algorithm="rquick", backend="sim")
    >>> np.asarray(psort(x, config=cfg))
    array([1, 2, 3, 4, 5, 6, 8, 9], dtype=int32)

    A batch of rows sorts within per-row subgroups of a (d, p) mesh — the
    rows never exchange elements:

    >>> xs = np.stack([x, x[::-1] * 10])
    >>> np.asarray(psort(xs, config=cfg))
    array([[ 1,  2,  3,  4,  5,  6,  8,  9],
           [10, 20, 30, 40, 50, 60, 80, 90]], dtype=int32)

    A hierarchical (2 × 2) mesh — same result, collectives split across
    the inter/intra axes:

    >>> np.asarray(psort(x, config=SortConfig(mesh_shape=(2, 2),
    ...                                       algorithm="rams",
    ...                                       backend="sim")))
    array([1, 2, 3, 4, 5, 6, 8, 9], dtype=int32)

    A sort that loses PE 3 restarts at the reduced power-of-two topology
    (4 PEs lose one → 3 survivors → p = 2) and still returns the exact
    sorted multiset:

    >>> from repro.core.comm import FaultPlan, kill_pe
    >>> from repro.runtime.failures import FaultPolicy
    >>> pol = FaultPolicy(plan=FaultPlan((kill_pe(3),)))
    >>> np.asarray(psort(x, config=cfg.replace(fault_policy=pol)))
    array([1, 2, 3, 4, 5, 6, 8, 9], dtype=int32)
    >>> [a["p"] for a in pol.attempts]
    [4, 2]
    >>> [e.primitive for e in pol.trace.injected()]
    ['fault:kill', 'rescale']

    A shard budget of 4 elements streams the 16-element-per-PE problem
    through the device in 4 runs per PE — same sorted output:

    >>> from repro.core.external import ExternalPolicy
    >>> big = np.arange(64, dtype=np.int32)[::-1].copy()
    >>> out = psort(big, config=SortConfig(
    ...     p=4, backend="sim", external=ExternalPolicy(budget=4)))
    >>> np.array_equal(np.asarray(out), np.sort(big))
    True
    """
    cfg = _coerce_config(config, legacy, caller="psort")
    p, algorithm, mesh = cfg.p, cfg.algorithm, cfg.mesh
    axis, data_axis = cfg.axis, cfg.data_axis
    mesh_shape, mesh_axes, levels = cfg.mesh_shape, cfg.mesh_axes, cfg.levels
    capacity_factor, backend = cfg.capacity_factor, cfg.backend
    cost_model, fault_policy = cfg.cost_model, cfg.fault_policy
    external = cfg.external
    algo_kw = dict(cfg.algo_kw)
    if levels is not None and algorithm not in ("auto", "rams", "ntb-ams"):
        raise ValueError(f"levels= applies to the multi-level AMS family "
                         f"(or 'auto'), not algorithm={algorithm!r}")
    keys = jnp.asarray(keys)
    if keys.ndim not in (1, 2):
        raise ValueError(f"keys must be 1-D (one sort) or 2-D (a batch of "
                         f"independent sorts); got shape {keys.shape}")
    batched = keys.ndim == 2
    d = keys.shape[0] if batched else 1
    if mesh_shape is not None:
        p_o, p_i = (int(v) for v in mesh_shape)
        if (p_o & (p_o - 1)) or (p_i & (p_i - 1)) or p_o < 1 or p_i < 1:
            raise ValueError(f"mesh_shape={mesh_shape} entries must be "
                             f"powers of two (hypercube layout)")
        if p is not None and p != p_o * p_i:
            raise ValueError(f"p={p} inconsistent with mesh_shape="
                             f"{tuple(mesh_shape)}")
        p = p_o * p_i
        if backend == "shard_map":
            if mesh is None:
                from repro.dist.sharding import sort_mesh
                mesh = sort_mesh(shape=(p_o, p_i), d=d if batched else 1,
                                 data_axis=data_axis, mesh_axes=mesh_axes)
            want = dict(zip(mesh_axes, (p_o, p_i)))
            if batched:
                want[data_axis] = d
            for a, sz in want.items():
                if mesh.shape.get(a) != sz:
                    raise ValueError(f"mesh axis {a!r} must have size {sz}; "
                                     f"mesh has {dict(mesh.shape)}")
        elif mesh is not None:
            raise ValueError("backend='sim' runs meshless; drop the mesh arg")
    elif backend == "shard_map":
        if batched:
            if mesh is None:
                from repro.dist.sharding import sort_mesh
                mesh = sort_mesh(p, d=d, axis=axis, data_axis=data_axis)
            for a in (data_axis, axis):
                if a not in mesh.shape:
                    raise ValueError(f"2-D keys need a mesh with axes "
                                     f"({data_axis!r}, {axis!r}); mesh has "
                                     f"{tuple(mesh.shape)}")
            if mesh.shape[data_axis] != d:
                raise ValueError(f"keys.shape[0]={d} != mesh.shape"
                                 f"[{data_axis!r}]={mesh.shape[data_axis]}")
        else:
            mesh = mesh or default_mesh(p, axis)
        p = mesh.shape[axis]
    else:
        if mesh is not None:
            raise ValueError("backend='sim' runs meshless; drop the mesh arg")
        if p is None:
            raise ValueError("backend='sim' needs an explicit p")
    if p & (p - 1):
        raise ValueError(f"p={p} must be a power of two (hypercube layout)")
    n = keys.shape[-1]
    orig_dtype = keys.dtype
    u = key_to_uint(keys)

    external = _resolve_external(external, backend)
    if external is not None:
        if backend != "sim":
            raise ValueError("external= requires backend='sim' (host-"
                             "streamed shards run on emulated PEs)")
        if batched:
            raise ValueError("external= supports 1-D keys only (each run "
                             "pass is one global sort problem)")
        if mesh_shape is not None:
            raise ValueError("external= runs on one flat axis; drop "
                             "mesh_shape")
    elif algorithm == "external":
        raise ValueError("algorithm='external' needs external="
                         "ExternalPolicy(...) (or REPRO_EXTERNAL_BUDGET)")

    if fault_policy is not None:
        if backend != "sim":
            raise ValueError("fault_policy= requires backend='sim' (the "
                             "fault-injection lane runs on emulated PEs)")
        return _psort_faulty(
            u, n, d, batched, orig_dtype, p=p, algorithm=algorithm,
            policy=fault_policy, axis=axis, data_axis=data_axis,
            mesh_shape=(p_o, p_i) if mesh_shape is not None else None,
            mesh_axes=mesh_axes, levels=levels,
            capacity_factor=capacity_factor, return_info=return_info,
            cost_model=cost_model, algo_kw=algo_kw, external=external,
            overlap=cfg.overlap)

    per = -(-max(n, 1) // p)                       # ceil(n/p)
    capacity = max(4, int(np.ceil(per * capacity_factor)))
    if algorithm == "auto":
        algorithm = selection.select_algorithm(
            n, p, model=cost_model, levels=levels, mesh_shape=mesh_shape,
            budget=external.budget if external is not None else None)
    if external is not None and (algorithm == "external"
                                 or per > external.budget):
        return _psort_external(u, n, orig_dtype, p=p, axis=axis,
                               policy=external, return_info=return_info,
                               overlap=cfg.overlap)
    if cfg.overlap and algorithm in _OVERLAP_ALGOS:
        algo_kw.setdefault("overlap", True)
    if algorithm in ("rams", "ntb-ams"):
        if mesh_shape is not None:
            from .rams import nested_level_bits
            algo_kw.setdefault(
                "level_bits", tuple(nested_level_bits(p_o, p_i, levels)))
        elif levels is not None:
            algo_kw.setdefault("levels", levels)
    out_capacity = _out_capacity(algorithm, n, p, per, capacity)

    pad = pad_value(u.dtype)
    row_counts = jnp.minimum(jnp.maximum(n - per * jnp.arange(p), 0),
                             per).astype(jnp.int32)
    kw = tuple(sorted(algo_kw.items()))
    # jit caches key on the local-kernel policy: the policy is read at
    # trace time, so without this a cached executable would silently
    # ignore a toggle between calls of the same signature.
    pl = local_kernels()
    if mesh_shape is not None:
        axes = ((mesh_axes[0], p_o), (mesh_axes[1], p_i))
        lead = (d,) if batched else ()
        flat = jnp.full(lead + (p * per,), pad, u.dtype)
        flat = flat.at[..., :n].set(u)
        keys_nd = flat.reshape(lead + (p_o, p_i, per))
        counts_nd = jnp.broadcast_to(row_counts.reshape(p_o, p_i),
                                     lead + (p_o, p_i))
        da = data_axis if batched else None
        if backend == "shard_map":
            keys_out, idx_out, counts_out, overflow = _psort_nested_jit(
                keys_nd, counts_nd, mesh, cfg, axis, da, axes, p, algorithm,
                capacity, out_capacity, kw, pallas=pl)
        else:
            keys_out, idx_out, counts_out, overflow = _psort_nested_sim_jit(
                keys_nd, counts_nd, cfg, axis, da, d, axes, p, algorithm,
                capacity, out_capacity, kw, pallas=pl)
        keys_out = keys_out.reshape((d, p) + keys_out.shape[-1:])
        idx_out = idx_out.reshape((d, p) + idx_out.shape[-1:])
        counts_out = counts_out.reshape(d, p)
        overflow = overflow.reshape(d, p)
    elif batched:
        flat = jnp.full((d, p * per), pad, u.dtype).at[:, :n].set(u)
        keys3d = flat.reshape(d, p, per)
        counts = jnp.broadcast_to(row_counts, (d, p))
        if backend == "shard_map":
            keys_out, idx_out, counts_out, overflow = _psort2_jit(
                keys3d, counts, mesh, cfg, axis, data_axis, p, algorithm,
                capacity, out_capacity, kw, pallas=pl)
        else:
            keys_out, idx_out, counts_out, overflow = _psort2_sim_jit(
                keys3d, counts, cfg, axis, data_axis, d, p, algorithm,
                capacity, out_capacity, kw, pallas=pl)
    else:
        flat = jnp.full((p * per,), pad, u.dtype).at[:n].set(u)
        keys2d = flat.reshape(p, per)
        if backend == "shard_map":
            keys_out, idx_out, counts_out, overflow = _psort_jit(
                keys2d, row_counts, mesh, cfg, axis, p, algorithm, capacity,
                out_capacity, kw, pallas=pl)
        else:
            keys_out, idx_out, counts_out, overflow = _psort_sim_jit(
                keys2d, row_counts, cfg, axis, p, algorithm, capacity,
                out_capacity, kw, pallas=pl)
        keys_out, idx_out = keys_out[None], idx_out[None]
        counts_out, overflow = counts_out[None], overflow[None]

    keys_out = np.asarray(keys_out)                # (d, p, out_capacity)
    counts_out = np.asarray(counts_out)            # (d, p)
    pe_range = range(1) if algorithm == "allgatherm" else range(p)
    rows = [np.concatenate([keys_out[r, i, :counts_out[r, i]]
                            for i in pe_range]) for r in range(d)]
    result = uint_to_key(jnp.asarray(np.stack(rows) if batched else rows[0]),
                         orig_dtype)
    if return_info:
        idx_out = np.asarray(idx_out)
        perms = [np.concatenate([idx_out[r, i, :counts_out[r, i]]
                                 for i in range(p)]) if n
                 else np.zeros((0,), np.uint32) for r in range(d)]
        info = {
            "algorithm": algorithm,
            "backend": backend,
            "mesh_shape": tuple(mesh_shape) if mesh_shape is not None
            else None,
            "counts": counts_out if batched else counts_out[0],
            "overflow": int(np.asarray(overflow).sum()),
            "balance": counts_out.max() / max(1.0, n / p),
            "perm": np.stack(perms) if batched else perms[0],
            "n": n,
            "d": d,
        }
        return result, info
    return result


def _out_capacity(algorithm: str, n: int, p: int, per: int, capacity: int) -> int:
    if algorithm in ("gatherm", "allgatherm"):
        return max(1, p * per)                     # concentrated output
    return capacity


def _resolve_external(external, backend: str):
    """Explicit policy wins; else ``REPRO_EXTERNAL_BUDGET`` (sim only)."""
    if external is not None:
        return external
    env = os.environ.get("REPRO_EXTERNAL_BUDGET")
    if env and backend == "sim":
        from .external import ExternalPolicy
        return ExternalPolicy(budget=int(env))
    return None


def _psort_external(u, n, orig_dtype, *, p, axis, policy, return_info,
                    overlap=False):
    """The non-fault ``psort(..., external=...)`` tail: run the four
    external passes once and reassemble the host output exactly like the
    in-core paths.  Ambient collectives decorators (``comm.counting()``)
    apply — the passes resolve ``impl`` per ``sim_map`` call."""
    from .external import _psort_external_once
    keys_out, idx_out, counts_out, overflow = _psort_external_once(
        u, n, axis=axis, p=p, policy=policy, impl=None, overlap=overlap)
    rows = np.concatenate([keys_out[0, pe, :counts_out[0, pe]]
                           for pe in range(p)])
    result = uint_to_key(jnp.asarray(rows), orig_dtype)
    if return_info:
        per = -(-max(n, 1) // p)
        perm = (np.concatenate([idx_out[0, pe, :counts_out[0, pe]]
                                for pe in range(p)]) if n
                else np.zeros((0,), np.uint32))
        info = {
            "algorithm": "external",
            "backend": "sim",
            "mesh_shape": None,
            "counts": counts_out[0],
            "overflow": int(np.asarray(overflow).sum()),
            "balance": counts_out.max() / max(1.0, n / p),
            "perm": perm,
            "n": n,
            "d": 1,
            "external": {
                "budget": policy.budget,
                "runs": max(1, -(-per // policy.budget)),
                "merge": policy.merge,
            },
        }
        return result, info
    return result


def _psort_sim_once(u, n, d, batched, *, axis, data_axis, p, mesh_shape,
                    mesh_axes, algorithm, capacity_factor, levels, algo_kw,
                    impl):
    """One sim-backend sort attempt at a fixed topology under ``impl``.

    The fault lane's executor: pads/redistributes the full key array over
    the *current* p, builds the per-PE body, and runs it under a **fresh**
    ``jax.jit`` — injection and counting act at trace time, so the cached
    module-level jits (which would replay nothing on a cache hit) cannot
    be used here.  Returns host arrays ``(keys, idx, counts, overflow)``
    of shapes ``(d, p, out_cap) ×2, (d, p) ×2``.
    """
    per = -(-max(n, 1) // p)
    capacity = max(4, int(np.ceil(per * capacity_factor)))
    kw = dict(algo_kw)
    if algorithm in ("rams", "ntb-ams"):
        if mesh_shape is not None:
            from .rams import nested_level_bits
            kw.setdefault("level_bits", tuple(nested_level_bits(
                mesh_shape[0], mesh_shape[1], levels)))
        elif levels is not None:
            kw.setdefault("levels", levels)
    out_capacity = _out_capacity(algorithm, n, p, per, capacity)
    body = _sort_body(axis, p, algorithm, capacity, out_capacity,
                      tuple(sorted(kw.items())))
    pad = pad_value(u.dtype)
    row_counts = jnp.minimum(jnp.maximum(n - per * jnp.arange(p), 0),
                             per).astype(jnp.int32)
    lead = (d,) if batched else ()
    flat = jnp.full(lead + (p * per,), pad, u.dtype)
    flat = flat.at[..., :n].set(u)
    da = data_axis if batched else None
    if mesh_shape is not None:
        p_o, p_i = mesh_shape
        axes = ((mesh_axes[0], p_o), (mesh_axes[1], p_i))
        keys_nd = flat.reshape(lead + (p_o, p_i, per))
        counts_nd = jnp.broadcast_to(row_counts.reshape(p_o, p_i),
                                     lead + (p_o, p_i))
        runner = comm.sim_map(body, axis, p, impl=impl, nested=axes,
                              mesh=(d, p) if batched else None, data_axis=da)
        k, i, c, o = jax.jit(runner)(keys_nd, counts_nd)
        k = k.reshape((d, p) + k.shape[-1:])
        i = i.reshape((d, p) + i.shape[-1:])
        c, o = c.reshape(d, p), o.reshape(d, p)
    elif batched:
        runner = comm.sim_map(body, axis, p, impl=impl, mesh=(d, p),
                              data_axis=da)
        k, i, c, o = jax.jit(runner)(flat.reshape(d, p, per),
                                     jnp.broadcast_to(row_counts, (d, p)))
    else:
        runner = comm.sim_map(body, axis, p, impl=impl)
        k, i, c, o = jax.jit(runner)(flat.reshape(p, per), row_counts)
        k, i, c, o = k[None], i[None], c[None], o[None]
    return np.asarray(k), np.asarray(i), np.asarray(c), np.asarray(o)


def _psort_faulty(u, n, d, batched, orig_dtype, *, p, algorithm, policy,
                  axis, data_axis, mesh_shape, mesh_axes, levels,
                  capacity_factor, return_info, cost_model, algo_kw,
                  external=None, overlap=False):
    """The ``psort(..., fault_policy=...)`` driver (sim backend).

    Attempt loop (bounded by ``repro.runtime.failures.run_with_restarts``):
    trace the sort afresh under ``FaultyCollectives`` executing the
    policy's surviving :class:`repro.core.comm.FaultPlan`; on a
    :class:`repro.core.comm.PEFailure` — raised by a fired kill, or by
    this driver for a watchdog-flagged straggler — exclude the PE, plan
    the reduced topology (``elastic.plan_sort_rescale``), record a
    ``rescale`` trace event carrying the new extent, and retry.  Progress
    = shrinking p, so a rescale that fails to shrink trips the loop's
    no-progress give-up rather than burning the restart budget.
    """
    from repro.runtime.elastic import plan_sort_rescale
    from repro.runtime.failures import flag_stragglers, run_with_restarts

    trace = policy.trace if policy.trace is not None else comm.CommTrace()
    policy.trace = trace
    log = policy.logger if policy.logger is not None else (lambda *a: None)
    plan0 = policy.plan if policy.plan is not None else comm.FaultPlan()
    if not isinstance(plan0, comm.FaultPlan):
        plan0 = comm.FaultPlan(tuple(plan0))
    state = {"p": p, "mesh_shape": mesh_shape, "plan": plan0,
             "failed": ()}
    policy.attempts.clear()

    def attempt(_start):
        p_cur, ms = state["p"], state["mesh_shape"]
        per_cur = -(-max(n, 1) // p_cur)
        algo = algorithm
        if algo == "auto":
            algo = selection.select_algorithm(
                n, p_cur, model=cost_model, levels=levels, mesh_shape=ms,
                budget=external.budget if external is not None else None)
        # external engages whenever the per-PE shard outgrows the budget —
        # a rescale shrinks p, so an attempt that started in-core can go
        # external after exclusion (and never the other way around)
        ext = external is not None and (algo == "external"
                                        or per_cur > external.budget)
        if ext:
            algo = "external"
        rec = {"p": p_cur, "mesh_shape": ms, "algorithm": algo, "ok": False}
        policy.attempts.append(rec)
        # faulty outside counting: a killed launch records its fault:kill
        # event but not the launch the dead PE never completed
        fc = comm.FaultyCollectives(
            comm.CountingCollectives(comm.SIM, trace), state["plan"], trace)
        if ext:
            from .external import _psort_external_once
            out = _psort_external_once(u, n, axis=axis, p=p_cur,
                                       policy=external, impl=fc,
                                       overlap=overlap)
        else:
            # overlap applies per attempt: the re-selected algorithm at the
            # reduced p may or may not have a streamable exchange
            kw_att = dict(algo_kw)
            if overlap and algo in _OVERLAP_ALGOS:
                kw_att.setdefault("overlap", True)
            out = _psort_sim_once(
                u, n, d, batched, axis=axis, data_axis=data_axis, p=p_cur,
                mesh_shape=ms, mesh_axes=mesh_axes, algorithm=algo,
                capacity_factor=capacity_factor, levels=levels,
                algo_kw=kw_att, impl=fc)
        times = [policy.base_step_time * fc.fired_delays.get(pe, 1.0)
                 for pe in range(p_cur)]
        slow = flag_stragglers(times, k_mad=policy.k_mad,
                               warmup=policy.warmup)
        if slow:
            raise comm.PEFailure(slow[0], phase="straggler")
        rec["ok"] = True
        return out + (p_cur, ms, algo)

    def rescale(e, restarts):
        p_cur, ms = state["p"], state["mesh_shape"]
        rplan = plan_sort_rescale(p_cur, (e.pe,), mesh_shape=ms)
        trace.add("rescale", 0, rplan.p_new, axis=axis, tag=e.phase,
                  pe=e.pe)
        why = "straggling" if e.phase == "straggler" else "failed"
        log(f"[psort] PE {e.pe} {why} at p={p_cur}; "
            f"rescaling to p={rplan.p_new}")
        state["p"] = rplan.p_new
        state["mesh_shape"] = rplan.mesh_shape
        state["plan"] = state["plan"].surviving(e.pe, rplan.p_new)
        state["failed"] += (e.pe,)

    keys_out, idx_out, counts_out, overflow, p_fin, ms_fin, algo_fin = \
        run_with_restarts(attempt, max_restarts=policy.max_restarts,
                          retry_on=(comm.PEFailure,), on_failure=rescale,
                          progress_fn=lambda: -state["p"], logger=log)

    pe_range = range(1) if algo_fin == "allgatherm" else range(p_fin)
    rows = [np.concatenate([keys_out[r, i, :counts_out[r, i]]
                            for i in pe_range]) for r in range(d)]
    result = uint_to_key(jnp.asarray(np.stack(rows) if batched else rows[0]),
                         orig_dtype)
    if return_info:
        perms = [np.concatenate([idx_out[r, i, :counts_out[r, i]]
                                 for i in range(p_fin)]) if n
                 else np.zeros((0,), np.uint32) for r in range(d)]
        info = {
            "algorithm": algo_fin,
            "backend": "sim",
            "mesh_shape": ms_fin,
            "counts": counts_out if batched else counts_out[0],
            "overflow": int(np.asarray(overflow).sum()),
            "balance": counts_out.max() / max(1.0, n / p_fin),
            "perm": np.stack(perms) if batched else perms[0],
            "n": n,
            "d": d,
            "fault": {
                "p_final": p_fin,
                "failed": state["failed"],
                "restarts": len(policy.attempts) - 1,
                "attempts": list(policy.attempts),
            },
            "comm_trace": trace,
        }
        return result, info
    return result


def trace_collectives(n: int, config=None, *args, d: int = 1,
                      **legacy) -> comm.CommTrace:
    """Count the collectives one ``psort`` call would launch, per PE.

    Takes the same :class:`SortConfig` as :func:`psort` (``d`` stays a
    direct keyword — it sizes the trace mesh, not the sort).  The legacy
    ``trace_collectives(n, p, algorithm, capacity_factor, ...)`` style
    still works through the deprecation shim.

    Abstractly evaluates the sim-backend body (shapes only, no FLOPs, no
    compile) under a :class:`repro.core.comm.CountingCollectives` decorator
    and returns the structured :class:`repro.core.comm.CommTrace`: launch
    counts, payload bytes and group sizes per primitive — the measured
    counterpart of the paper's Table I, and the feature vector
    ``benchmarks/calibrate.py`` fits the :class:`CostModel` against.

    ``d > 1`` traces the batched body over a (d, p) sim mesh instead.
    Collectives resolve relative to the sort axis, so the per-PE trace is
    independent of the data-axis extent — the subgroup-isolation property
    EXPERIMENTS.md's "Subgroup sort" grid is generated from.

    ``mesh_shape=(p_outer, p_inner)`` traces the **hierarchical** path:
    the counter sits inside the nested view, so every recorded event
    carries the real axis it targeted (``mesh_axes``) and the RAMS phase
    tag — ``trace.by_axis()`` splits inter- from intra-axis volume,
    ``trace.by_tag()`` attributes it per level.  ``levels`` forwards to
    the AMS level schedule exactly as in :func:`psort`.

    >>> from repro.core.api import SortConfig, trace_collectives
    >>> bt = SortConfig(p=8, algorithm="bitonic")
    >>> t1 = trace_collectives(64, bt)
    >>> t1.counts()["ppermute"] >= 6            # d·(d+1)/2 exchange rounds
    True
    >>> t2 = trace_collectives(64, bt, d=4)
    >>> t2.summary() == t1.summary()            # per-PE trace: no d term
    True

    On a nested mesh, RAMS crosses the slow outer axis with exactly one
    level's all_to_all (plus the initial shuffle) — every other level is
    intra-only:

    >>> t = trace_collectives(64 * 32, SortConfig(mesh_shape=(4, 16),
    ...                                           algorithm="rams"))
    >>> t.filter(primitive="all_to_all", axis="inter").tags()
    ['level0', 'shuffle']
    >>> [tag for tag, s in sorted(t.by_tag().items())
    ...  if "all_to_all" in s["counts"]]
    ['level0', 'level1', 'shuffle']

    ``external=ExternalPolicy(...)`` traces the out-of-core lane instead.
    Unlike the in-core trace this *executes* (splitter values steer the
    pass structure, so shapes alone don't determine the trace) on a
    deterministic seeded input — the trace is reproducible and additionally
    carries the injected ``ext:h2d``/``ext:d2h`` I/O pseudo-events
    (:meth:`repro.core.comm.CommTrace.io_bytes`) with per-pass tags:

    >>> from repro.core.external import ExternalPolicy
    >>> t = trace_collectives(256, SortConfig(
    ...     p=4, external=ExternalPolicy(budget=16)))
    >>> sorted(tag for tag in t.tags() if tag.startswith("ext:pass"))
    ['ext:pass0', 'ext:pass1', 'ext:pass2', 'ext:pass3']
    >>> t.io_bytes() > 0 and t.io_bytes() == t.filter(tag="ext:runs"
    ...     ).io_bytes() + t.filter(tag="ext:merge").io_bytes()
    True
    """
    if args:
        names = ("algorithm", "capacity_factor")
        if len(args) > len(names):
            raise TypeError(f"trace_collectives() takes at most "
                            f"{len(names)} legacy positional arguments "
                            f"after n/p ({names}); got {len(args)}")
        legacy.update(zip(names, args))
    cfg = _coerce_config(config, legacy, caller="trace_collectives")
    p, algorithm = cfg.p, cfg.algorithm
    capacity_factor, levels = cfg.capacity_factor, cfg.levels
    mesh_shape, mesh_axes = cfg.mesh_shape, cfg.mesh_axes
    external = cfg.external
    algo_kw = dict(cfg.algo_kw)
    if external is not None:
        if d > 1 or mesh_shape is not None:
            raise ValueError("external tracing covers the 1-D flat axis "
                             "only (the external lane's contract)")
        if p is None or p & (p - 1):
            raise ValueError(f"p={p} must be a power of two")
        from .external import _psort_external_once
        rng = np.random.default_rng(0xE87)
        u = jnp.asarray(rng.integers(0, 2 ** 32, size=max(n, 1),
                                     dtype=np.int64).astype(np.uint32))
        counter = comm.CountingCollectives(comm.SIM)
        _psort_external_once(u, n, axis="sort", p=p, policy=external,
                             impl=counter, overlap=cfg.overlap)
        return counter.trace
    axes = None
    if mesh_shape is not None:
        p_o, p_i = (int(v) for v in mesh_shape)
        if p is not None and p != p_o * p_i:
            raise ValueError(f"p={p} inconsistent with mesh_shape="
                             f"{tuple(mesh_shape)}")
        p = p_o * p_i
        axes = ((mesh_axes[0], p_o), (mesh_axes[1], p_i))
    if p is None:
        raise ValueError("trace_collectives needs p or mesh_shape")
    if p & (p - 1):
        raise ValueError(f"p={p} must be a power of two (hypercube layout)")
    if algorithm == "auto":
        algorithm = selection.select_algorithm(n, p, model=cfg.cost_model,
                                               levels=levels,
                                               mesh_shape=mesh_shape)
    if cfg.overlap and algorithm in _OVERLAP_ALGOS:
        algo_kw.setdefault("overlap", True)
    if algorithm in ("rams", "ntb-ams"):
        if mesh_shape is not None:
            from .rams import nested_level_bits
            algo_kw.setdefault(
                "level_bits", tuple(nested_level_bits(p_o, p_i, levels)))
        elif levels is not None:
            algo_kw.setdefault("levels", levels)
    per = -(-max(n, 1) // p)
    capacity = max(4, int(np.ceil(per * capacity_factor)))
    out_capacity = _out_capacity(algorithm, n, p, per, capacity)
    body = _sort_body("sort", p, algorithm, capacity, out_capacity,
                      tuple(sorted(algo_kw.items())))
    counter = comm.CountingCollectives(comm.SIM)
    mesh = (d, p) if d > 1 else None
    runner = comm.sim_map(body, "sort", p, impl=counter, mesh=mesh,
                          data_axis="data" if d > 1 else None, nested=axes)
    axis_lead = (p_o, p_i) if axes is not None else (p,)
    lead = ((d,) + axis_lead) if d > 1 else axis_lead
    jax.eval_shape(runner,
                   jax.ShapeDtypeStruct(lead + (per,), jnp.uint32),
                   jax.ShapeDtypeStruct(lead, jnp.int32))
    return counter.trace
