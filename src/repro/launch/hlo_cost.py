"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
every computation once: a ``while`` body that a scanned 96-layer model
executes 96 times is counted *once*, so FLOPs/bytes/collective traffic of
scan-based models are wildly understated.  This module re-derives the three
roofline inputs by walking the HLO computation graph bottom-up and scaling
``while`` bodies by their ``known_trip_count`` backend_config (emitted by
XLA for lax.scan loops).

Counting conventions (per device — the module is the per-device program):
  flops:   dot = 2·(result elems)·(contraction size); elementwise/reduce =
           result elems (dots dominate every model here)
  bytes:   Σ operand sizes + result size per instruction, fusion-internal
           instructions excluded (same convention as XLA bytes-accessed on
           the post-fusion module)
  colls:   wire bytes per collective kind; all-reduce counted 2× operand
           (reduce-scatter + all-gather phases of a ring)
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count[\\"\s:{]+n[\\"\s:]+(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


def _shape_bytes_elems(type_str: str):
    """Total (bytes, elems) over a possibly-tuple type string."""
    bytes_, elems = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return bytes_, elems


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0       # upper bound: every fusion-boundary tensor
    bytes_min: float = 0.0   # lower bound: dots/copies/collectives/slices
                             # only — models a perfectly-fused TPU pipeline
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    unknown_trip: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += v * mult
        self.unknown_trip += other.unknown_trip


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, list] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cost_memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        comment = re.compile(r"/\*.*?\*/")
        for raw in text.splitlines():
            line = comment.sub("", raw)
            s = line.strip()
            if not s or s.startswith("//"):
                continue
            # computation header: "%name (args) -> type {"  /  "ENTRY %name ..."
            if s.endswith("{") and "->" in s and "=" not in s.split("->")[0]:
                is_entry = s.startswith("ENTRY")
                name = s.split()[1 if is_entry else 0].lstrip("%")
                name = name.split("(")[0]
                cur = name
                self.computations[cur] = []
                if is_entry:
                    self.entry = cur
                continue
            if s == "}":
                cur = None
                continue
            if cur is not None:
                self.computations[cur].append(line)

    # -- per-computation cost ------------------------------------------------

    def cost_of(self, comp: str) -> Cost:
        if comp in self._cost_memo:
            return self._cost_memo[comp]
        self._cost_memo[comp] = Cost()          # break cycles defensively
        total = Cost()
        shapes: Dict[str, str] = {}
        for line in self.computations.get(comp, ()):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, opcode, rest = m.groups()
            shapes[name] = type_str
            total.add(self._instr_cost(opcode, type_str, rest, shapes))
        self._cost_memo[comp] = total
        return total

    def _instr_cost(self, opcode: str, type_str: str, rest: str,
                    shapes: Dict[str, str]) -> Cost:
        c = Cost()
        res_bytes, res_elems = _shape_bytes_elems(type_str)
        op = opcode.replace("-start", "")

        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "copy-start", "copy-done", "all-reduce-done",
                  "all-gather-done", "all-to-all-done",
                  "collective-permute-done", "opt-barrier"):
            return c

        if op in ("dynamic-update-slice", "dynamic-slice"):
            # in-place update / windowed read: traffic is the slice, not the
            # whole buffer (otherwise scan grad-accumulation counts the full
            # parameter stack per layer iteration)
            args = rest.split(")")[0] if ")" in rest else rest
            names = _OPERAND_RE.findall(args)
            if op == "dynamic-slice":
                c.bytes += 2 * res_bytes
            else:
                upd = names[1] if len(names) > 1 else None
                ub = _shape_bytes_elems(shapes.get(upd, ""))[0] if upd else 0
                c.bytes += 2 * ub
            c.bytes_min += c.bytes
            return c

        # operand bytes
        opnd_bytes = 0
        args = rest.split(")")[0] if ")" in rest else rest
        for o in _OPERAND_RE.findall(args):
            if o in shapes:
                b, _ = _shape_bytes_elems(shapes[o])
                opnd_bytes += b

        if op in COLLECTIVES:
            wire = res_bytes if op == "all-gather" else max(opnd_bytes, res_bytes)
            mult = 2 if op == "all-reduce" else 1
            c.coll_bytes[op] += wire * mult
            c.coll_count[op] += 1
            c.bytes += opnd_bytes + res_bytes
            c.bytes_min += opnd_bytes + res_bytes
            return c

        if op == "while":
            body = cond = None
            mb = re.search(r"body=%?([\w\.\-]+)", rest)
            mc = _COND_RE.search(rest)
            body = mb.group(1) if mb else None
            cond = mc.group(1) if mc else None
            mt = _TRIP_RE.search(rest)
            trips = int(mt.group(1)) if mt else 1
            if not mt:
                c.unknown_trip += 1
            if body:
                c.add(self.cost_of(body), trips)
            if cond:
                c.add(self.cost_of(cond), trips + 1)
            return c

        if op == "conditional":
            mb = _BRANCHES_RE.search(rest)
            if mb:
                branches = [b.strip().lstrip("%") for b in
                            mb.group(1).split(",")]
                costs = [self.cost_of(b) for b in branches if b]
                if costs:
                    c.add(max(costs, key=lambda x: x.flops))
            c.bytes += opnd_bytes + res_bytes
            return c

        if op in ("fusion", "call", "custom-call", "map", "reduce",
                  "reduce-window", "sort", "scatter", "select-and-scatter"):
            mcalls = _CALLS_RE.search(rest)
            c.bytes += opnd_bytes + res_bytes
            if op == "fusion" and mcalls:
                inner = self.cost_of(mcalls.group(1))
                c.flops += inner.flops            # bytes stay fusion-boundary
                c.add(Cost(coll_bytes=inner.coll_bytes,
                           coll_count=inner.coll_count))
            elif op in ("call", "map") and mcalls:
                c.add(self.cost_of(mcalls.group(1)))
            elif op == "sort":
                import math
                c.flops += res_elems * max(1.0, math.log2(max(res_elems, 2)))
            else:
                c.flops += res_elems
            return c

        if op == "dot":
            k = 1
            mcon = _CONTRACT_RE.search(rest)
            lhs = _OPERAND_RE.findall(rest.split(")")[0])
            if mcon and lhs and lhs[0] in shapes:
                sm = _SHAPE_RE.search(shapes[lhs[0]])
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d.strip()]
                    for ci in mcon.group(1).split(","):
                        if ci.strip() and int(ci) < len(dims):
                            k *= dims[int(ci)]
            c.flops += 2.0 * res_elems * k
            c.bytes += opnd_bytes + res_bytes
            c.bytes_min += opnd_bytes + res_bytes
            return c

        if op == "convolution":
            c.flops += 2.0 * res_elems * max(1, opnd_bytes // max(res_bytes, 1))
            c.bytes += opnd_bytes + res_bytes
            c.bytes_min += opnd_bytes + res_bytes
            return c

        if op == "copy":
            c.bytes += opnd_bytes + res_bytes
            c.bytes_min += opnd_bytes + res_bytes
            return c

        # elementwise & everything else
        c.flops += res_elems
        c.bytes += opnd_bytes + res_bytes
        return c

    def entry_cost(self) -> Cost:
        entry = self.entry
        if entry is None:
            for name in self.computations:
                if name.startswith(("main", "jit_")) or ".main" in name:
                    entry = name
                    break
        if entry is None and self.computations:
            entry = next(iter(self.computations))
        return self.cost_of(entry) if entry else Cost()


def analyze(hlo_text: str, entry: Optional[str] = None) -> dict:
    mod = HloModule(hlo_text)
    c = mod.cost_of(entry) if entry else mod.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "bytes_min": c.bytes_min,
        "collective_bytes": dict(c.coll_bytes),
        "collective_counts": dict(c.coll_count),
        "unknown_trip_counts": c.unknown_trip,
    }
