"""Batched serving driver: prefill-free token generation against a KV
cache / recurrent state, with request batching and per-step latency stats.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --tokens 64 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.dist.sharding import make_shardings
from repro.launch import steps as S
from repro.launch.mesh import make_mesh_shape
from repro.launch.sort_serve import latency_stats
from repro.models import transformer as T


def next_token_input(nxt, batch: int) -> dict:
    """Normalize a sampler output to the serve step's ``(batch, 1)`` int32
    token contract.

    Accepts ``(batch,)`` or ``(batch, 1)``.  Anything wider — e.g. a
    multi-head sampler's ``(batch, heads)`` — is ambiguous: the old
    ``reshape(batch, 1)[..., :1]`` fallback silently fed head 0's token
    stream interleaved across heads.  Reduce to one token per sequence
    before feeding; this boundary now rejects everything else.
    """
    if nxt.ndim == 1:
        nxt = nxt[:, None]
    if nxt.shape != (batch, 1):
        raise ValueError(
            f"sampler output shape {nxt.shape} does not satisfy the "
            f"(batch={batch}, 1) next-token contract; reduce multi-head "
            "samples to one token per sequence before feeding")
    return {"tokens": nxt.astype(jnp.int32)}


def serve(cfg, mesh, *, batch: int, tokens: int, cache_len: int = 256,
          seed: int = 0, logger=print):
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    if mesh is not None:
        pshard = make_shardings(jax.eval_shape(lambda: params), cfg, mesh)
        params = jax.tree.map(jax.device_put, params, pshard)
    dstate = T.init_decode_state(cfg, batch, cache_len, jnp.bfloat16)
    step = jax.jit(S.make_serve_step(cfg, mesh), donate_argnums=(1,))

    r = np.random.default_rng(seed)
    if cfg.family == "audio":
        inp = {"embeds": jnp.asarray(
            r.normal(size=(batch, 1, cfg.d_model)), jnp.bfloat16)}
    else:
        inp = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab, size=(batch, 1)), jnp.int32)}

    lat = []
    out_tokens = []
    for t in range(tokens):
        t0 = time.perf_counter()
        if mesh is not None:
            with mesh:
                nxt, dstate = step(params, dstate, inp)
        else:
            nxt, dstate = step(params, dstate, inp)
        nxt.block_until_ready()
        lat.append(time.perf_counter() - t0)
        out_tokens.append(np.asarray(nxt))
        if cfg.family != "audio":
            inp = next_token_input(nxt, batch)
    # first step times compilation; with <= 1 post-warmup samples the
    # stats come back None-valued with a note instead of bogus percentiles
    stats = latency_stats(lat, warmup=1, rate_scale=batch, note_ctx="step")
    stats["tok_per_s"] = stats.pop("per_s")
    if stats["p50_ms"] is None:
        logger(f"[serve] {cfg.name}: {tokens} steps, batch {batch}: "
               f"{stats['note']}")
    else:
        logger(f"[serve] {cfg.name}: {tokens} steps, batch {batch}: "
               f"p50 {stats['p50_ms']:.2f}ms p99 {stats['p99_ms']:.2f}ms "
               f"{stats['tok_per_s']:.0f} tok/s")
    return np.concatenate(out_tokens, axis=0), stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mesh", default=None, help="data,model (optional)")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = None
    if args.mesh:
        dd, mm = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh_shape((dd, mm), ("data", "model"))
    serve(cfg, mesh, batch=args.batch, tokens=args.tokens)


if __name__ == "__main__":
    main()
