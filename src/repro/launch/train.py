"""End-to-end training driver.

Runs real steps on the available devices (CPU here; the same code path
drives TPU pods — only the mesh shape changes).  Integrates the full
runtime: sharded state, deterministic data pipeline, async checkpointing,
crash recovery, straggler watchdog, and (for small replicated models) the
int8 compressed gradient all-reduce.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --steps 50 --mesh 1,2 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, smoke_variant
from repro.data.pipeline import TokenPipeline
from repro.dist.sharding import data_axes_of, make_shardings
from repro.launch import steps as S
from repro.launch.mesh import make_mesh_shape
from repro.models import transformer as T
from repro.runtime import CheckpointManager, StepWatchdog, run_with_restarts


def build_everything(cfg, mesh, batch, seq, seed=0):
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    pshard = make_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, pshard)
    step_fn, opt_init = S.make_train_step(cfg, mesh)
    opt = opt_init(params)
    oshard = make_shardings(jax.eval_shape(lambda: opt), cfg, mesh)
    opt = jax.tree.map(lambda x, s: jax.device_put(x, s), opt, oshard)
    state = S.TrainState(params, opt, jnp.zeros((), jnp.int32))
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    return state, jitted, (pshard, oshard)


def train(cfg, mesh, *, steps: int, batch: int, seq: int,
          ckpt_dir=None, ckpt_every: int = 20, log_every: int = 10,
          crash_at=None, logger=print):
    pipe = TokenPipeline(cfg.vocab, batch, seq, family=cfg.family,
                         d_model=cfg.d_model, n_codebooks=cfg.n_codebooks)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    watchdog = StepWatchdog()
    pending_fault = [crash_at]

    def run(start_step: int) -> int:
        state, jitted, shards = build_everything(cfg, mesh, batch, seq)
        if mgr and mgr.latest_step() is not None:
            state = mgr.restore(state)
            logger(f"[train] restored step {int(state.step)}")
        losses = []
        with mesh:
            for step in range(int(state.step), steps):
                if pending_fault[0] is not None and step == pending_fault[0]:
                    pending_fault[0] = None      # fault fires once
                    raise RuntimeError(f"injected fault at step {step}")
                watchdog.start()
                batch_np = pipe.batch_at(step)
                state, metrics = jitted(state, batch_np)
                loss = float(metrics["loss"])
                losses.append(loss)
                slow = watchdog.stop(step)
                if slow:
                    logger(f"[watchdog] straggler step {step}: "
                           f"{watchdog.times[-1]:.3f}s")
                if step % log_every == 0:
                    logger(f"[train] step {step} loss {loss:.4f} "
                           f"lr {float(metrics['lr']):.2e} "
                           f"gnorm {float(metrics['grad_norm']):.3f}")
                if mgr and (step + 1) % ckpt_every == 0:
                    mgr.save_async(step + 1, state)
        if mgr:
            mgr.wait()
            mgr.save(steps, state)
        return steps, losses

    if mgr:
        result = run_with_restarts(lambda s: run(s), ckpt_manager=mgr)
    else:
        result = run(0)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1,2",
                    help="data,model axis sizes (CPU devices)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    dd, mm = (int(x) for x in args.mesh.split(","))
    mesh = make_mesh_shape((dd, mm), ("data", "model"))
    t0 = time.time()
    final, losses = train(cfg, mesh, steps=args.steps, batch=args.batch,
                          seq=args.seq, ckpt_dir=args.ckpt_dir)
    dt = time.time() - t0
    print(f"[train] done: {final} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} → {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
