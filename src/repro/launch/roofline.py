"""Aggregate the per-cell dry-run records into the §Roofline table.

  PYTHONPATH=src python -m repro.launch.roofline [--dir launch_results]
                                                 [--markdown]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

HW = "v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 4×50 GB/s ICI links per chip"


def load(dir_: Path, pod: str = "pod1", variant: str = "base"):
    recs = []
    for f in sorted(dir_.glob(f"*__{pod}*.json")):
        r = json.loads(f.read_text())
        if r.get("variant", "base") != variant:
            continue
        recs.append(r)
    return recs


def fmt_row(r):
    if r["status"] == "skipped":
        return None
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | ERROR | | | | | |"
    t = r["roofline"]
    dom = r["dominant"].replace("_s", "")
    step = max(t.values())
    frac = t["compute_s"] / step if step else 0.0
    ratio = r.get("useful_flops_ratio")
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | {dom} | "
            f"{ratio:.2f} | {frac:.1%} |")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(Path(__file__).resolve().parents[3]
                                         / "launch_results"))
    ap.add_argument("--pod", default="pod1")
    args = ap.parse_args(argv)
    recs = load(Path(args.dir), args.pod)
    print(f"Roofline terms per (arch × shape), single-pod 256 chips ({HW})\n")
    print("| arch | shape | T_comp [s] | T_mem [s] | T_coll [s] | dominant |"
          " 6ND/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    skips = []
    for r in recs:
        row = fmt_row(r)
        if row is None:
            skips.append((r["arch"], r["shape"], r["reason"]))
        else:
            print(row)
    if skips:
        print("\nSkipped cells (per brief):")
        for a, s, why in skips:
            print(f"  - {a} × {s}: {why}")


if __name__ == "__main__":
    main()
