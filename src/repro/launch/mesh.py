"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod : (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
the slowest collectives (DCN-ish), so only FSDP/grad reductions cross it.
Elastic variants for restore-time resharding are produced by
``make_mesh_shape`` with any axis sizes.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}, found {len(devs)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # jax.make_mesh consumes exactly prod(shape) devices; slice explicitly so
    # the single-pod mesh also works when 512 emulated devices exist.
    return jax.make_mesh(shape, axes, devices=devs[:ndev])


def make_mesh_shape(shape: Sequence[int], axes: Sequence[str]):
    """Elastic mesh builder (checkpoint restore onto a different topology)."""
    ndev = int(np.prod(shape))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         devices=jax.devices()[:ndev])


def make_sort_mesh(p: Optional[int] = None, axis: str = "sort"):
    """1-D mesh for the standalone sorting workloads (configs/sortbench)."""
    devs = jax.devices()
    p = p or len(devs)
    return jax.make_mesh((p,), (axis,), devices=devs[:p])
