"""train_step / serve_step / prefill_step factories + input_specs.

``input_specs(cfg, shape, mesh)`` returns ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, zero allocation) — the
dry-run lowers against these; train.py/serve.py feed real arrays of the
same shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import data_axes_of, make_shardings
from repro.models import transformer as T
from repro.optim import cosine_schedule, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _dp_for_batch(mesh, B: int, cfg=None):
    if not mesh:
        return ()
    import numpy as _np
    from repro.dist.sharding import batch_axes_of
    if cfg is not None:
        return batch_axes_of(mesh, cfg, batch=B)
    dp = data_axes_of(mesh)
    sz = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    return dp if dp and B % sz == 0 else ()


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the step inputs of (arch × shape)."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_for_batch(mesh, B, cfg)
    bs = (lambda *s: NamedSharding(mesh, P(dp, *s))) if mesh else \
        (lambda *s: None)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            return {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16, bs(None, None)),
                    "labels": _sds((B, S, cfg.n_codebooks), jnp.int32, bs(None, None))}
        out = {"tokens": _sds((B, S), jnp.int32, bs(None))}
        if shape.kind == "train":
            out["labels"] = _sds((B, S), jnp.int32, bs(None))
        return out
    # decode: one new token; the KV cache / state is part of the step inputs
    if cfg.family == "audio":
        return {"embeds": _sds((B, 1, cfg.d_model), jnp.bfloat16, bs(None, None))}
    return {"tokens": _sds((B, 1), jnp.int32, bs(None))}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh]):
    """ShapeDtypeStructs + shardings for the decode state."""
    B, S = shape.global_batch, shape.seq_len
    state_shape = jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, S, jnp.bfloat16))
    if mesh is None:
        return state_shape
    dp = _dp_for_batch(mesh, B)
    msize = mesh.shape.get("model", 1)

    def spec_of(leaf):
        shp = leaf.shape
        # stacked (L, B, ...) tensors: shard B over data; prefer sharding the
        # head/heads dim over model when divisible, else the length dim.
        if len(shp) >= 3:
            rest = [None] * (len(shp) - 2)
            # KV cache (L,B,S,KV,hd) / ssm state (L,B,H,P,N) / conv (L,B,k,C)
            if len(shp) == 5 and shp[3] % msize == 0:      # KV heads
                rest[1] = "model"
            elif len(shp) == 5 and shp[2] % msize == 0:    # cache length / H
                rest[0] = "model"
            elif len(shp) == 4 and shp[2] % msize == 0:
                rest[0] = "model"
            return NamedSharding(mesh, P(None, dp, *rest))
        return NamedSharding(mesh, P())

    return jax.tree.map(
        lambda leaf: _sds(leaf.shape, leaf.dtype, spec_of(leaf)), state_shape)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], *,
                    peak_lr: float = 3e-4, warmup: int = 200,
                    total: int = 10000):
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    dax = data_axes_of(mesh) if mesh else ("data",)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        lr = cosine_schedule(state.step, peak_lr=peak_lr, warmup=warmup,
                             total=total)
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg, mesh, dax))(state.params)
        new_params, new_opt = opt_update(grads, state.opt, state.params, lr=lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return (TrainState(new_params, new_opt, state.step + 1),
                {"loss": loss, "lr": lr, "grad_norm": gnorm})

    return train_step, opt_init


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh]):
    dax = data_axes_of(mesh) if mesh else ("data",)

    def serve_step(params, dstate, inputs):
        logits, new_state = T.decode_step(params, dstate, inputs, cfg, mesh,
                                          dax)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_state

    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh]):
    dax = data_axes_of(mesh) if mesh else ("data",)

    def prefill_step(params, inputs):
        logits, _ = T.forward(params, inputs, cfg, mesh, dax,
                              last_only=getattr(cfg, "prefill_last_only",
                                                False))
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


# ---------------------------------------------------------------------------
# Abstract state + shardings (used by dryrun and train init)
# ---------------------------------------------------------------------------


def abstract_state(cfg: ModelConfig, mesh: Optional[Mesh], *,
                   with_opt: bool = True, seed: int = 0):
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(seed), cfg))
    pshard = make_shardings(params_shape, cfg, mesh) if mesh else None
    if not with_opt:
        return params_shape, pshard
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    oshard = make_shardings(opt_shape, cfg, mesh) if mesh else None
    return (params_shape, opt_shape), (pshard, oshard)


def sharded_specs(shape_tree, shard_tree):
    if shard_tree is None:
        return shape_tree
    return jax.tree.map(lambda s, sh: _sds(s.shape, s.dtype, sh),
                        shape_tree, shard_tree)
