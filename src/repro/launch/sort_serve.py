"""Sort-as-a-service: a continuous-batching query frontend over the
(data × sort) machinery.

Requests (``sort`` / ``top_k`` / ``rank_of_key`` / ``percentile`` /
``range_query``) arrive on a FIFO queue; :class:`SortService` drains them
in **micro-batches** — each :meth:`SortService.step` takes the kind at
the head of the queue, collects every queued request of that kind (up to
``max_batch``, FIFO order preserved), and answers the whole group with
*one* batched launch of the corresponding ``core/queries.py`` primitive.
The batch is a barrier: all requests in it complete together, and each is
charged the same device latency (its end-to-end latency additionally
includes its queue wait).  This is continuous batching in the serving
sense — arrivals during a step join the queue and ride the next one.

Per query kind the service routes between two paths:

  * **selection** — the sort-free primitives of ``core/queries.py``
    (O(n/p + coll·(rounds + log p)), no all-to-all);
  * **fullsort** — answer by indexing a resident fully sorted copy,
    built once on first use by :func:`repro.core.psort` and then
    amortized across every later query.

``policy="auto"`` consults the cost model
(:func:`repro.core.selection.select_algorithm` with ``query=``), which
charges a full sort to the query batch — the one-shot-data call; a
long-lived service that expects to amortize can pin ``policy="fullsort"``
(or ``"selection"`` to never materialize the sort).

  PYTHONPATH=src python -m repro.launch.sort_serve --smoke
  PYTHONPATH=src python -m repro.launch.sort_serve --n 1048576 --p 64 \
      --queries 200 --mix top_k=4,percentile=2,rank_of_key=2,range_query=1
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import SortConfig, psort, queries, selection
from repro.core.queries import QUERY_KINDS


def latency_stats(lat, warmup: int = 1, rate_scale: float = 1.0,
                  note_ctx: str = "sample") -> Dict[str, Any]:
    """Percentile summary of a latency series, robust to tiny samples.

    Drops the ``warmup`` leading samples (they time compilation, not
    steady state).  When nothing remains — e.g. a single-step run — the
    percentiles would just echo the compile time, so the stats come back
    as ``None`` with an explanatory ``note`` instead of a misleading
    number.  ``rate_scale`` converts mean step latency into a rate
    (items per second): pass the number of items one sample covers.
    """
    lat = np.asarray(lat, dtype=float)
    post = lat[warmup:]
    if post.size == 0:
        return {"p50_ms": None, "p99_ms": None, "per_s": None,
                "n": int(lat.size),
                "note": f"{lat.size} {note_ctx}(s) <= warmup={warmup}: "
                        "not enough post-warmup samples for percentiles"}
    return {"p50_ms": float(np.percentile(post, 50) * 1e3),
            "p99_ms": float(np.percentile(post, 99) * 1e3),
            "per_s": float(rate_scale / post.mean()),
            "n": int(post.size)}


_ids = itertools.count()


@dataclasses.dataclass
class Request:
    """One queued query.  ``arg`` per kind: top_k → k, percentile → q,
    rank_of_key → key, range_query → (lo, hi), sort → None."""
    kind: str
    arg: Any = None
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    t_submit: float = 0.0


@dataclasses.dataclass
class Result:
    request: Request
    value: Any
    path: str                 # "selection" | "fullsort" | "sort"
    batch: int                # micro-batch size this request rode in
    step_s: float             # device latency of the batched launch
    latency_s: float          # submit → done (includes queue wait)


class SortService:
    """Continuous-batching query service over one resident dataset."""

    def __init__(self, keys, p: Optional[int] = None, *,
                 config: Optional[SortConfig] = None, backend: str = "sim",
                 axis: str = "sort", mesh=None, policy: str = "auto",
                 model: Optional[selection.CostModel] = None,
                 max_batch: int = 64, clock=time.perf_counter):
        """``config`` (a :class:`repro.core.SortConfig`) carries the sort
        knobs (p / backend / axis / mesh / cost_model / overlap / ...);
        the direct keywords remain as the legacy spelling and default
        ``backend="sim"`` (a service usually fronts emulated PEs).  The
        service-level knobs — ``policy``, ``max_batch``, ``clock`` — are
        not sort parameters and stay direct-only."""
        if policy not in ("auto", "selection", "fullsort"):
            raise ValueError(f"unknown policy {policy!r}")
        if config is None:
            config = SortConfig(p=p, backend=backend, axis=axis, mesh=mesh,
                                cost_model=model)
        elif p is not None and config.p not in (None, p):
            raise ValueError(f"p={p} inconsistent with config.p={config.p}")
        elif config.p is None and p is not None:
            config = config.replace(p=p)
        if config.p is None:
            raise ValueError("SortService needs p (directly or via config)")
        self.config = config
        self.keys = np.asarray(keys)
        self.data = queries.shard_data(self.keys, config.p)
        self.backend = config.backend
        self.axis = config.axis
        self.mesh = config.mesh
        self.policy = policy
        self.model = config.cost_model
        self.max_batch = max_batch
        self.clock = clock
        self.queue: deque = deque()
        self.completed: List[Result] = []
        self._sorted: Optional[np.ndarray] = None   # lazy fullsort cache
        self._bits = self.data.bits

    # -- request intake ---------------------------------------------------

    def submit(self, kind: str, arg: Any = None) -> int:
        if kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; "
                             f"know {QUERY_KINDS}")
        req = Request(kind, arg, t_submit=self.clock())
        self.queue.append(req)
        return req.id

    # -- routing ----------------------------------------------------------

    def route(self, kind: str, batch: int) -> str:
        """Which path a micro-batch takes: the explicit policy, or the
        cost model's call (once the fullsort cache exists it is free to
        index, so auto switches to it for count/rank queries it can
        answer locally... except answers must stay device-resident
        semantics — we keep auto on the model's verdict for fidelity)."""
        if kind == "sort":
            return "sort"
        if self.policy != "auto":
            return self.policy
        ks = [r.arg for r in self.queue if r.kind == "top_k"]
        verdict = selection.select_algorithm(
            self.data.n, self.data.p, config=self.config, query=kind,
            batch=batch, k=max(ks) if ks else None, bits=self._bits)
        return "selection" if verdict == "selection" else "fullsort"

    # -- execution --------------------------------------------------------

    def _full_sorted(self) -> np.ndarray:
        if self._sorted is None:
            self._sorted = psort(self.keys,
                                 config=self.config.replace(p=self.data.p))
        return self._sorted

    def _answer_selection(self, kind: str, args: list):
        kw = dict(backend=self.backend, axis=self.axis, mesh=self.mesh)
        if kind == "top_k":
            out = queries.top_k(self.data, np.asarray(args, np.int64), **kw)
            return list(out)
        if kind == "percentile":
            return list(queries.percentile(self.data,
                                           np.asarray(args, float), **kw))
        if kind == "rank_of_key":
            lt, le = queries.rank_of_key(self.data, np.asarray(args), **kw)
            return list(zip(lt.tolist(), le.tolist()))
        lo = np.asarray([a[0] for a in args])
        hi = np.asarray([a[1] for a in args])
        return list(queries.range_query(self.data, lo, hi, **kw))

    def _answer_fullsort(self, kind: str, args: list):
        s = self._full_sorted()
        n = len(s)
        if kind == "top_k":
            return [s[n - int(k):] for k in args]
        if kind == "percentile":
            idx = np.floor(np.asarray(args, float) / 100.0 * (n - 1))
            return list(s[idx.astype(np.int64)])
        if kind == "rank_of_key":
            a = np.asarray(args, s.dtype)
            return list(zip(np.searchsorted(s, a, "left").tolist(),
                            np.searchsorted(s, a, "right").tolist()))
        lo = np.asarray([a[0] for a in args], s.dtype)
        hi = np.asarray([a[1] for a in args], s.dtype)
        return list(np.maximum(np.searchsorted(s, hi, "left") -
                               np.searchsorted(s, lo, "left"), 0))

    def step(self) -> List[Result]:
        """Drain one micro-batch: the head-of-queue kind, FIFO, up to
        ``max_batch`` requests, one batched launch."""
        if not self.queue:
            return []
        kind = self.queue[0].kind
        batch: List[Request] = []
        rest: deque = deque()
        while self.queue and len(batch) < self.max_batch:
            r = self.queue.popleft()
            (batch if r.kind == kind else rest).append(r)
        while self.queue:
            rest.append(self.queue.popleft())
        self.queue = rest
        path = self.route(kind, len(batch))
        t0 = self.clock()
        if kind == "sort":
            vals = [self._full_sorted() for _ in batch]
        elif path == "selection":
            vals = self._answer_selection(kind, [r.arg for r in batch])
        else:
            vals = self._answer_fullsort(kind, [r.arg for r in batch])
        t1 = self.clock()
        out = [Result(r, v, path, len(batch), t1 - t0, t1 - r.t_submit)
               for r, v in zip(batch, vals)]
        self.completed.extend(out)
        return out

    def drain(self) -> List[Result]:
        done: List[Result] = []
        while self.queue:
            done.extend(self.step())
        return done

    # -- reporting --------------------------------------------------------

    def stats(self, warmup: int = 1) -> Dict[str, Dict[str, Any]]:
        """Per-kind end-to-end latency stats over completed requests
        (None-safe — see :func:`latency_stats`), plus an overall block
        with queries/s across every kind."""
        out: Dict[str, Dict[str, Any]] = {}
        for kind in QUERY_KINDS:
            lat = [r.latency_s for r in self.completed
                   if r.request.kind == kind]
            if lat:
                out[kind] = latency_stats(lat, warmup=warmup,
                                          note_ctx="request")
        all_lat = [r.latency_s for r in self.completed]
        if all_lat:
            total = latency_stats(all_lat, warmup=warmup,
                                  note_ctx="request")
            # queries/s over device-busy time: each micro-batch launch
            # counts once, not once per request it carried
            steps = {}
            for r in self.completed:
                steps.setdefault((r.request.kind, round(r.step_s, 9)),
                                 r.step_s)
            busy = sum(steps.values())
            total["queries_per_s"] = (len(all_lat) / busy) if busy > 0 \
                else None
            out["overall"] = total
        return out


# ---------------------------------------------------------------------------
# CLI driver: synthetic mixed-query stream
# ---------------------------------------------------------------------------


def _gen_stream(rng, n, count, mix: Dict[str, int], key_pool):
    kinds = [k for k, w in mix.items() for _ in range(w)]
    for _ in range(count):
        kind = kinds[rng.integers(len(kinds))]
        if kind == "top_k":
            yield kind, int(rng.integers(1, min(64, n) + 1))
        elif kind == "percentile":
            yield kind, float(rng.uniform(0, 100))
        elif kind == "rank_of_key":
            yield kind, key_pool[rng.integers(len(key_pool))]
        elif kind == "range_query":
            a = key_pool[rng.integers(len(key_pool))]
            b = key_pool[rng.integers(len(key_pool))]
            yield kind, (min(a, b), max(a, b))
        else:
            yield kind, None


def parse_mix(text: str) -> Dict[str, int]:
    mix = {}
    for part in text.split(","):
        k, _, w = part.partition("=")
        k = k.strip()
        if k not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {k!r} in --mix")
        mix[k] = int(w) if w else 1
    return mix


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=1 << 20)
    ap.add_argument("--p", type=int, default=64)
    ap.add_argument("--queries", type=int, default=None,
                    help="query count (default 100; 24 under --smoke)")
    ap.add_argument("--mix", default="top_k=4,percentile=2,rank_of_key=2,"
                                     "range_query=1")
    ap.add_argument("--policy", default="auto",
                    choices=("auto", "selection", "fullsort"))
    ap.add_argument("--backend", default="sim",
                    choices=("sim", "shard_map"))
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny instance: n=4096, p=8, 24 queries")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n, args.p = 4096, 8
    if args.queries is None:
        args.queries = 24 if args.smoke else 100

    rng = np.random.default_rng(args.seed)
    keys = rng.integers(0, 1 << 32, size=args.n).astype(np.int64)
    svc = SortService(keys, config=SortConfig(p=args.p,
                                              backend=args.backend),
                      policy=args.policy, max_batch=args.max_batch)
    mix = parse_mix(args.mix)
    pool = keys[rng.integers(0, args.n, size=256)]
    for kind, arg in _gen_stream(rng, args.n, args.queries, mix, pool):
        svc.submit(kind, arg)
    t0 = time.perf_counter()
    done = svc.drain()
    wall = time.perf_counter() - t0
    print(f"[sort_serve] n={args.n} p={args.p} backend={args.backend} "
          f"policy={args.policy}: {len(done)} queries in {wall:.3f}s")
    for kind, st in svc.stats().items():
        if st.get("p50_ms") is None:
            print(f"  {kind:>12}: n={st['n']}  ({st['note']})")
            continue
        extra = f"  {st['queries_per_s']:.1f} q/s" \
            if st.get("queries_per_s") else ""
        print(f"  {kind:>12}: n={st['n']}  p50 {st['p50_ms']:.2f}ms  "
              f"p99 {st['p99_ms']:.2f}ms{extra}")
    return svc


if __name__ == "__main__":
    main()
