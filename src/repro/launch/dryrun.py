import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production meshes, record memory/cost analysis and the
collective schedule for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out-dir ...]

One process per cell is recommended (``--all`` spawns subprocesses) so a
single XLA OOM/compile failure cannot take down the sweep and per-cell
peak RSS stays bounded on this 1-core/35 GB container.
"""
import argparse                      # noqa: E402
import json                          # noqa: E402
import re                            # noqa: E402
import subprocess                    # noqa: E402
import sys                           # noqa: E402
import time                          # noqa: E402
from pathlib import Path             # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "launch_results"

# v5e constants for the roofline terms (per chip)
PEAK_FLOPS = 197e12            # bf16
HBM_BW = 819e9                 # bytes/s
ICI_BW_LINK = 50e9             # bytes/s per link; v5e: 4 links usable/chip
ICI_LINKS = 4

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}


def parse_collectives(hlo: str):
    """Sum wire bytes per collective kind from post-SPMD HLO text.

    Conventions (ring algorithms, per participating device):
      all-gather: result bytes (each device receives ~full result)
      all-reduce: 2 × operand bytes (reduce-scatter + all-gather phases)
      reduce-scatter / all-to-all / collective-permute: operand≈result bytes
    """
    sums = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
            "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(sums, 0)
    for m in _COLL_RE.finditer(hlo):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d.strip():
                nbytes *= int(d)
        mult = 2 if kind == "all-reduce" else 1
        sums[kind] += nbytes * mult
        counts[kind] += 1
    return sums, counts


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             variant: str = "base"):
    import jax
    import jax.numpy as jnp
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch import steps as S
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if variant != "base":
        cfg = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "multi_pod": multi_pod, "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=why)
        return _dump(rec, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(jnp.prod(jnp.asarray(list(mesh.shape.values()))))
    rec["mesh"] = dict(mesh.shape)
    t0 = time.time()
    try:
        with mesh:
            if shape.kind == "train":
                (pshape, oshape), (pshard, oshard) = S.abstract_state(cfg, mesh)
                step_fn, _ = S.make_train_step(cfg, mesh)
                state_in = S.TrainState(
                    S.sharded_specs(pshape, pshard),
                    S.sharded_specs(oshape, oshard),
                    jax.ShapeDtypeStruct((), jnp.int32))
                batch = S.input_specs(cfg, shape, mesh)
                jitted = jax.jit(step_fn, donate_argnums=(0,))
                lowered = jitted.lower(state_in, batch)
            elif shape.kind == "prefill":
                pshape, pshard = S.abstract_state(cfg, mesh, with_opt=False)
                step_fn = S.make_prefill_step(cfg, mesh)
                lowered = jax.jit(step_fn).lower(
                    S.sharded_specs(pshape, pshard),
                    S.input_specs(cfg, shape, mesh))
            else:  # decode
                pshape, pshard = S.abstract_state(cfg, mesh, with_opt=False)
                step_fn = S.make_serve_step(cfg, mesh)
                lowered = jax.jit(step_fn, donate_argnums=(1,)).lower(
                    S.sharded_specs(pshape, pshard),
                    S.cache_specs(cfg, shape, mesh),
                    S.input_specs(cfg, shape, mesh))
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # trip-count-aware analysis (XLA cost_analysis counts scan bodies
        # once — see hlo_cost.py); keep XLA's numbers for reference.
        from repro.launch import hlo_cost
        hc = hlo_cost.analyze(hlo)
        colls = hc["collective_bytes"]
        coll_counts = hc["collective_counts"]
        flops = float(hc["flops"])
        # memory term uses the TPU-fused lower bound (dots/copies/slices/
        # collectives); the CPU fusion-boundary upper bound is reported too.
        bytes_hbm = float(hc["bytes_min"])
        bytes_upper = float(hc["bytes"])
        coll_bytes = float(sum(colls.values()))
        t_comp = flops / PEAK_FLOPS
        t_mem = bytes_hbm / HBM_BW
        t_coll = coll_bytes / (ICI_LINKS * ICI_BW_LINK)
        terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
        dominant = max(terms, key=terms.get)
        model_flops = _model_flops(cfg, shape)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            memory={k: int(getattr(mem, k)) for k in
                    ("generated_code_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "temp_size_in_bytes")
                    if hasattr(mem, k)},
            hlo_flops_per_device=flops,
            hlo_bytes_per_device=bytes_hbm,
            hlo_bytes_upper_per_device=bytes_upper,
            xla_cost_flops=float(cost.get("flops", 0.0)),
            xla_cost_bytes=float(cost.get("bytes accessed", 0.0)),
            unknown_trip_counts=hc["unknown_trip_counts"],
            collective_bytes_per_device=colls,
            collective_counts=coll_counts,
            roofline=terms, dominant=dominant,
            model_flops_global=model_flops,
            useful_flops_ratio=(model_flops / (flops * n_chips)
                                if flops else None),
            n_chips=n_chips,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:2000])
    return _dump(rec, out_dir)


def _model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = new tokens only."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch          # one token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * tokens)


def apply_variant(cfg, variant: str):
    """§Perf variants (hill-climbing knobs), applied over the base config."""
    import dataclasses
    mods = {
        "banded_swa": dict(swa_banded=True),
        "remat_dots": dict(remat="dots"),
        "remat_none": dict(remat="none"),
        "moe_dense": dict(moe_impl="dense"),
        "moe_sort": dict(moe_impl="sort"),
        "moe_tp_fused": dict(moe_tp_fused=True),
        "prefill_last": dict(prefill_last_only=True),
        "moe_tp_fused_remat_dots": dict(moe_tp_fused=True, remat="dots"),
        "prefill_last_banded": dict(prefill_last_only=True, swa_banded=True),
        "seq_parallel": dict(act_seq_shard=True),
        "seq_parallel_tp_moe": dict(act_seq_shard=True, moe_tp_fused=True),
        "context_parallel": dict(attn_context_parallel=True),
        "ddp": dict(ddp=True),
        "ddp_dots": dict(ddp=True, remat="dots"),
        "cp_last": dict(attn_context_parallel=True, prefill_last_only=True),
    }[variant]
    return dataclasses.replace(cfg, **mods)


def _dump(rec, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "pod2" if rec["multi_pod"] else "pod1"
    name = f"{rec['arch']}__{rec['shape']}__{tag}"
    if rec.get("variant", "base") != "base":
        name += f"__{rec['variant']}"
    path = out_dir / f"{name}.json"
    path.write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = rec.get("dominant", rec.get("reason", rec.get("error", "")))
    print(f"[dryrun] {name}: {status} ({str(extra)[:120]})", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)
    out_dir = Path(args.out_dir)

    if args.all:
        from repro.configs import SHAPES, list_archs
        cells = [(a, s, mp) for a in list_archs() for s in SHAPES
                 for mp in ((False, True) if args.both_meshes
                            else (args.multi_pod,))]
        failures = 0
        for arch, shp, mp in cells:
            tag = "pod2" if mp else "pod1"
            fname = out_dir / f"{arch}__{shp}__{tag}.json"
            if args.skip_existing and fname.exists() and \
                    json.loads(fname.read_text()).get("status") in ("ok", "skipped"):
                print(f"[dryrun] skip existing {fname.name}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shp, "--out-dir", str(out_dir)]
            if mp:
                cmd.append("--multi-pod")
            r = subprocess.run(cmd, check=False)
            failures += r.returncode != 0
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                   args.variant)
    if rec["status"] == "error":
        print(rec["error"], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
