"""Synthetic token pipeline + length-balanced batching via the paper's sort.

The pipeline is deterministic-per-step (seeded by step index), sharded by
host, and restart-safe: resuming from step k regenerates exactly the batch
stream from k (checkpoint stores only the step counter — the fault-recovery
path in runtime/failures.py relies on this).

``length_balanced_batches`` demonstrates the paper's technique in the data
layer: examples are distributed-sorted by (length, id) — a BucketSorted-
adversarial key distribution — so that each global batch packs
similar-length sequences (less padding waste).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class TokenPipeline:
    """Deterministic synthetic LM data (zipf-ish token stream)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 family: str = "dense", d_model: int = 0, n_codebooks: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.family = family
        self.d_model = d_model
        self.n_codebooks = n_codebooks

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        r = np.random.default_rng((self.seed, step))
        if self.family == "audio":
            emb = r.normal(0, 1, size=(self.batch, self.seq, self.d_model)
                           ).astype(np.float32)
            lab = r.integers(0, self.vocab,
                             size=(self.batch, self.seq, self.n_codebooks))
            return {"embeds": emb, "labels": lab.astype(np.int32)}
        # zipf-distributed tokens, shifted labels
        z = r.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def length_balanced_batches(lengths: np.ndarray, batch: int, p: int = None,
                            algorithm: str = "auto"):
    """Group example ids into batches of similar length via distributed sort.

    Keys = lengths (massively duplicated for natural data — the robustness
    case), payload = example id.  Returns (batches (n//batch, batch) ids,
    padding_waste_ratio_before, after).
    """
    import jax
    from repro.core.api import SortConfig, psort

    n = len(lengths)
    p = p or min(8, len(jax.devices()))
    out, info = psort(lengths.astype(np.int32),
                      config=SortConfig(p=p, algorithm=algorithm),
                      return_info=True)
    order = np.asarray(info["perm"]).astype(np.int64)
    nb = n // batch
    batches = order[:nb * batch].reshape(nb, batch)

    def waste(idx):
        ls = lengths[idx.reshape(-1)].reshape(idx.shape)
        return float(np.mean(1.0 - ls / np.maximum(ls.max(axis=1, keepdims=True), 1)))

    naive = np.arange(nb * batch).reshape(nb, batch)
    return batches, waste(naive), waste(batches)
