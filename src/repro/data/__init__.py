from .distributions import INSTANCES, generate_instance      # noqa: F401
from .pipeline import TokenPipeline, length_balanced_batches  # noqa: F401
