"""The paper's benchmark input instances (Helman et al. [5] + §VII).

Each generator returns the *local* input for PE ``i`` of ``p`` as an int64
numpy array of ``m = n/p`` keys in [0, 2^32).  These are the inputs the
robustness claims are tested against:

  Uniform      independent random values
  Gaussian     independent Gaussian values
  BucketSorted locally random, globally sorted (hits hypercube routing)
  g-Group      g = √p groups, PE-correlated placement
  Zero         all elements equal
  DeterDupl    only log p distinct keys
  RandDupl     32 local buckets filled with values from 0..31
  Staggered    PE-correlated halves (hard for hypercube splits)
  Mirrored     bit-reversed PE ranges — √p·⌊n/√p⌋ concentration after
               log(p)/2 naive quicksort recursions (paper §VII)
  AllToOne     last element of PE i is p−i; naive k-way sample sort sends
               min(p, n/p) messages to PE 0 on level 1
  Reverse      globally reverse-sorted
"""
from __future__ import annotations

import numpy as np

_M32 = np.int64(2 ** 32 - 1)


def _rng(seed, i):
    return np.random.default_rng((seed * 1_000_003 + i) & 0x7FFFFFFF)


def uniform(i, p, m, seed=0):
    return _rng(seed, i).integers(0, 2 ** 32, size=m, dtype=np.int64)


def gaussian(i, p, m, seed=0):
    g = _rng(seed, i).normal(2 ** 31, 2 ** 28, size=m)
    return np.clip(g, 0, float(_M32)).astype(np.int64)


def bucket_sorted(i, p, m, seed=0):
    lo = (2 ** 32 // p) * i
    hi = lo + (2 ** 32 // p)
    return _rng(seed, i).integers(lo, max(hi, lo + 1), size=m, dtype=np.int64)


def g_group(i, p, m, seed=0):
    g = max(1, int(np.sqrt(p)))
    grp = (i + p // 2) % g                     # PE→group, offset pattern
    width = 2 ** 32 // g
    lo = grp * width
    return _rng(seed, i).integers(lo, lo + width, size=m, dtype=np.int64)


def zero(i, p, m, seed=0):
    return np.zeros(m, dtype=np.int64)


def deter_dupl(i, p, m, seed=0):
    k = max(1, int(np.log2(max(p, 2))))
    return _rng(seed, i).integers(0, k, size=m, dtype=np.int64)


def rand_dupl(i, p, m, seed=0):
    r = _rng(seed, i)
    sizes = r.multinomial(m, np.ones(32) / 32)
    vals = r.integers(0, 32, size=32)
    return np.repeat(vals, sizes).astype(np.int64)


def staggered(i, p, m, seed=0):
    # PE i gets values concentrated in the "staggered" partner range
    half = p // 2 or 1
    j = (i // 2 + (i % 2) * half) % p
    width = 2 ** 32 // p
    lo = j * width
    return _rng(seed, i).integers(lo, lo + width, size=m, dtype=np.int64)


def _bit_reverse(x, bits):
    r = 0
    for _ in range(bits):
        r = (r << 1) | (x & 1)
        x >>= 1
    return r


def mirrored(i, p, m, seed=0):
    bits = max(1, p.bit_length() - 1)
    mi = _bit_reverse(i, bits)
    lo = (2 ** 31 // max(mi, 1)) if mi else 2 ** 31
    hi = 2 ** 31 // (mi + 1)
    lo, hi = min(lo, hi), max(lo, hi) + 1
    return _rng(seed, i).integers(lo, hi, size=m, dtype=np.int64)


def all_to_one(i, p, m, seed=0):
    r = _rng(seed, i)
    lo = min(p + (p - i) * ((2 ** 32 - p) // p), 2 ** 32 - 2)
    hi = min(p + (p - i + 1) * ((2 ** 32 - p) // p), 2 ** 32 - 1)
    out = r.integers(lo, max(hi, lo + 1), size=m, dtype=np.int64)
    if m:
        out[-1] = p - i
    return out


def reverse(i, p, m, seed=0):
    width = 2 ** 32 // p
    lo = (p - 1 - i) * width
    base = _rng(seed, i).integers(lo, lo + width, size=m, dtype=np.int64)
    return -np.sort(-base)


INSTANCES = {
    "Uniform": uniform, "Gaussian": gaussian, "BucketSorted": bucket_sorted,
    "g-Group": g_group, "Zero": zero, "DeterDupl": deter_dupl,
    "RandDupl": rand_dupl, "Staggered": staggered, "Mirrored": mirrored,
    "AllToOne": all_to_one, "Reverse": reverse,
}


def generate_instance(name: str, p: int, n: int, seed: int = 0):
    """Global array (n,) formed from the per-PE generators (PE-major)."""
    gen = INSTANCES[name]
    per = -(-n // p) if n else 0
    parts = []
    left = n
    for i in range(p):
        m = min(per, left)
        parts.append(gen(i, p, m, seed))
        left -= m
    return np.concatenate(parts) if parts else np.zeros(0, np.int64)
